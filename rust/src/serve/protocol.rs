//! Wire protocol of the serving layer: length-prefixed little-endian
//! binary frames over TCP (see [`super`] for the layer overview and
//! DESIGN.md §6 for the full contract).
//!
//! Every frame is `[u32 payload_len (LE)][payload]`, where the payload's
//! first byte is the frame kind. Integers are little-endian; strings are
//! `u32 len + UTF-8 bytes`; a target is `u8 kind (0 = stream, 1 =
//! group) + u64 index`. Frames and direction:
//!
//! | frame     | dir | payload                                                      |
//! |-----------|-----|--------------------------------------------------------------|
//! | `HELLO`   | c→s | magic `"THNG"`, version `u16`                                |
//! | `WELCOME` | s→c | version, engine str, n_streams, n_groups, group_width, chunk_rows, max_fill |
//! | `LEASE`   | c→s | req id, target, resume `u8` (0 = plain, 1 = tracked), cursor `u64`, dist |
//! | `LEASED`  | s→c | req id, leaf `h` (`u64`), `xs_origin` (`4 × u32`), cursor `u64` |
//! | `FILL`    | c→s | req id, target, rows `u64`, repeat `u32`, deadline_ms `u64` (0 = none), tag `u64`, dist |
//! | `DATA`    | s→c | req id, seq `u32`, last `u8`, count `u32`, values (`count × u32`) |
//! | `ERR`     | s→c | req id, seq, last, error code `u16` + 2×`u64` + message str  |
//! | `CANCEL`  | c→s | req id — abort the fill's not-yet-executed sub-requests      |
//! | `STATS_REQ` | c→s | req id, cursor `u64` (0 = full snapshot)                   |
//! | `STATS`   | s→c | req id, cursor, delta `u8`, counters, gauges, histograms     |
//! | `TRACE_REQ` | c→s | req id                                                     |
//! | `TRACE`   | s→c | req id, Chrome trace-event JSON str                          |
//! | `BYE`     | c→s | (empty)                                                      |
//! | `BYE_ACK` | s→c | (empty)                                                      |
//!
//! A STATS payload carries three `u32`-counted lists: counters and
//! gauges as `(str name, u64 value)` pairs, histograms as `(str name,
//! u64 count, u64 sum, u32 n_buckets, n × (u8 log2-index, u64 count))`
//! — buckets are sparse (only nonzero ones cross the wire), so an idle
//! histogram costs its name plus 21 bytes. The reply cursor names the
//! snapshot the server just retained; echo it in the next STATS_REQ for
//! a delta (`delta = 1`), send 0 (or an evicted cursor) for a full
//! snapshot.
//!
//! A `dist` field is `u8 kind` (0 = raw fill) followed, for kind ≠ 0,
//! by two `u64` carrying the [`DistSpec`] parameters as `f64` bits; the
//! decoder validates the parameter domain through
//! [`DistSpec::from_wire`], so an out-of-domain or non-finite spec is a
//! typed [`Error::Protocol`] before the server allocates anything for
//! the fill.
//!
//! Anything malformed — bad magic, unknown kind, oversized or truncated
//! frames, trailing bytes, or a client frame carrying the reserved
//! [`CONNECTION_REQ`] request id — decodes to a typed
//! [`Error::Protocol`], never a panic; a clean close *between* frames
//! reads as `Ok(None)`.

use std::io::{Read, Write};

use crate::coordinator::ReqTarget;
use crate::dist::DistSpec;
use crate::error::Error;
use crate::obs::{HistSnapshot, StatsSnapshot, HIST_BUCKETS};

/// Protocol version spoken by this crate (negotiated in HELLO/WELCOME).
/// v2 added the request-lifecycle surface: the FILL deadline field and
/// the CANCEL frame. v3 added the multi-tenant surface: the FILL QoS
/// tag, tracked LEASEs with resumption cursors, and the reserved-req-id
/// rejection. v4 added distribution shaping: the FILL/LEASE dist field
/// (DATA then carries shaped rows in the [`crate::dist`] encoding). v5
/// added observability: STATS_REQ/STATS (snapshot + delta-since-cursor
/// metric export) and TRACE_REQ/TRACE (Chrome trace-event dump).
pub const VERSION: u16 = 5;

/// Connection magic, first bytes of every HELLO.
pub const MAGIC: [u8; 4] = *b"THNG";

/// Upper bound on one frame's payload (64 MiB): anything larger is
/// rejected before allocation, so a garbage length prefix cannot ask the
/// peer to reserve gigabytes.
pub const MAX_FRAME: usize = 1 << 26;

/// Request id the server uses on ERR frames about the *connection*
/// rather than any one request (malformed frame, handshake violation):
/// clients surface these directly as the failure of whatever call was
/// in progress. The sentinel is *reserved*: a client frame carrying it
/// as its request id is rejected at decode time as a typed
/// [`Error::Protocol`] (it would otherwise collide with connection-level
/// error routing), and [`RemoteClient`](super::RemoteClient) never
/// allocates it.
pub const CONNECTION_REQ: u64 = u64::MAX;

const K_HELLO: u8 = 1;
const K_WELCOME: u8 = 2;
const K_LEASE: u8 = 3;
const K_LEASED: u8 = 4;
const K_FILL: u8 = 5;
const K_DATA: u8 = 6;
const K_ERR: u8 = 7;
const K_BYE: u8 = 8;
const K_BYE_ACK: u8 = 9;
const K_CANCEL: u8 = 10;
const K_STATS_REQ: u8 = 11;
const K_STATS: u8 = 12;
const K_TRACE_REQ: u8 = 13;
const K_TRACE: u8 = 14;

/// One decoded wire frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Client hello: magic + protocol version (client → server).
    Hello {
        /// The client's [`VERSION`].
        version: u16,
    },
    /// Server greeting: the serving shape a client needs to validate
    /// targets locally and size its fills (server → client).
    Welcome {
        /// The server's [`VERSION`].
        version: u16,
        /// Engine kind serving this endpoint (`"native"`, `"sharded"`, ..).
        engine: String,
        /// Streams served (ids `0..n_streams`).
        n_streams: u64,
        /// State-sharing groups served.
        n_groups: u64,
        /// Streams per group.
        group_width: u32,
        /// The server's preferred sub-fill granularity, in rows.
        chunk_rows: u32,
        /// Max numbers one FILL sub-request may ask for.
        max_fill: u64,
    },
    /// Validate-and-identify a target before filling from it.
    Lease {
        /// Client-chosen request id, echoed in the reply.
        req: u64,
        /// The stream or group to lease.
        target: ReqTarget,
        /// `None` is a plain (untracked) lease. `Some(cursor)` asks the
        /// server to *track* this target — retain a bounded tail of
        /// delivered values and a row cursor — and to resume delivery
        /// from absolute row `cursor`: rows the server already pushed
        /// past the cursor (e.g. down a connection that died mid-fill)
        /// are replayed from the retention ring before fresh generation
        /// continues. `Some(0)` on first contact just turns tracking on.
        resume: Option<u64>,
        /// Shaping spec this lease's fills (and its retention/replay
        /// state) are keyed on: shaped and raw deliveries of one target
        /// are tracked separately, so a resumption cursor counts rows
        /// in ONE consistent encoding. `None` is a raw lease.
        dist: Option<DistSpec>,
    },
    /// Lease granted; for stream targets carries the registered identity
    /// (zeroes for group targets).
    Leased {
        /// The LEASE's request id.
        req: u64,
        /// The stream's leaf constant (0 for groups).
        h: u64,
        /// The stream's decorrelator origin state (zeroes for groups).
        xs_origin: [u32; 4],
        /// The server's row cursor for a tracked target (how many rows
        /// it has routed to clients so far); 0 for plain leases.
        cursor: u64,
    },
    /// Fetch `repeat` consecutive sub-requests of `rows` rows each from
    /// `target`; answered by exactly `repeat` DATA/ERR frames in seq
    /// order (a cancelled or expired sub-request answers as a typed
    /// ERR — the reply count never changes).
    Fill {
        /// Client-chosen request id, echoed on every reply chunk.
        req: u64,
        /// The stream or group to drain.
        target: ReqTarget,
        /// Rows per sub-request (numbers for a stream target, rows ×
        /// group_width numbers for a group target).
        rows: u64,
        /// Sub-requests in this fill (≥ 1).
        repeat: u32,
        /// Milliseconds the fill may wait for service before its
        /// remaining sub-requests expire as retryable
        /// `DeadlineExceeded` ERR chunks (0 = no deadline). The clock
        /// is the server's monotonic clock, started when the FILL is
        /// read off the socket.
        deadline_ms: u64,
        /// QoS class (tenant tag) of this fill: the server drains
        /// pending fills weighted-fair across tags and enforces the
        /// per-tenant in-flight quota per tag. Tag 0 is the default
        /// class.
        tag: u64,
        /// Shape the fill into a distribution: `rows` then counts
        /// shaped samples and the reply DATA frames carry the shaped
        /// encoding ([`crate::dist`] — 2 LE words per f64 sample, 1
        /// word per discrete sample). `None` is a raw fill.
        dist: Option<DistSpec>,
    },
    /// Abort a fill's not-yet-executed sub-requests (client → server).
    /// Best-effort and idempotent: sub-requests already executed (or
    /// executing) deliver their real DATA; the rest resolve as
    /// `Cancelled` ERR chunks. Delivered chunks always form a
    /// contiguous prefix of the fill.
    ///
    /// Frames are processed in order by one reader per session, so a
    /// CANCEL takes effect only once the preceding FILL's submission
    /// loop has finished — and that loop blocks while the session
    /// window is full of frames the client is not reading. A client
    /// that wants responsive cancellation should keep reading replies
    /// (the window then never blocks for long), split huge fills
    /// across several FILLs, or — the hard abort — close the
    /// connection, which makes the server abandon the fill's
    /// unsubmitted remainder outright.
    Cancel {
        /// The FILL's request id.
        req: u64,
    },
    /// One successful sub-request's numbers.
    Data {
        /// The FILL's request id.
        req: u64,
        /// Sub-request index within the fill (`0..repeat`).
        seq: u32,
        /// Is this the fill's final sub-request?
        last: bool,
        /// The fetched numbers.
        values: Vec<u32>,
    },
    /// One failed sub-request (or a rejected request), as a typed
    /// [`enum@Error`] — check [`Error::is_retryable`]; a failed
    /// sub-request consumed nothing, so later sub-requests of the same
    /// fill continue the sequence seamlessly.
    Err {
        /// The offending request id.
        req: u64,
        /// Sub-request index within the fill.
        seq: u32,
        /// Is this the fill's final sub-request?
        last: bool,
        /// What went wrong.
        error: Error,
    },
    /// Ask for the server's metric export (client → server). Answered
    /// by exactly one STATS frame; interleaves freely with fills.
    StatsReq {
        /// Client-chosen request id, echoed in the reply.
        req: u64,
        /// Cursor from a previous STATS reply for a delta, or 0 for a
        /// full snapshot.
        cursor: u64,
    },
    /// The server's metric export (server → client).
    Stats {
        /// The STATS_REQ's request id.
        req: u64,
        /// Cursor naming the snapshot the server retained for this
        /// reply — echo it next time for a delta.
        cursor: u64,
        /// Whether `snap` is a delta against the requested cursor
        /// (counters and histogram buckets are differences; gauges are
        /// always absolute levels).
        delta: bool,
        /// The metric families (sorted by name).
        snap: StatsSnapshot,
    },
    /// Ask for the server's request-lifecycle trace dump (client →
    /// server). Answered by exactly one TRACE frame; empty rings (or
    /// tracing disabled) still answer, with an event-less document.
    TraceReq {
        /// Client-chosen request id, echoed in the reply.
        req: u64,
    },
    /// The server's trace dump (server → client).
    Trace {
        /// The TRACE_REQ's request id.
        req: u64,
        /// Chrome trace-event JSON (load at `chrome://tracing`).
        json: String,
    },
    /// Graceful goodbye (client → server): the server flushes every
    /// in-flight reply, answers BYE_ACK, and closes.
    Bye,
    /// Goodbye acknowledged — always the connection's last frame.
    ByeAck,
}

/// Short frame name for error messages.
pub(crate) fn frame_name(frame: &Frame) -> &'static str {
    match frame {
        Frame::Hello { .. } => "HELLO",
        Frame::Welcome { .. } => "WELCOME",
        Frame::Lease { .. } => "LEASE",
        Frame::Leased { .. } => "LEASED",
        Frame::Fill { .. } => "FILL",
        Frame::Data { .. } => "DATA",
        Frame::Err { .. } => "ERR",
        Frame::Cancel { .. } => "CANCEL",
        Frame::StatsReq { .. } => "STATS_REQ",
        Frame::Stats { .. } => "STATS",
        Frame::TraceReq { .. } => "TRACE_REQ",
        Frame::Trace { .. } => "TRACE",
        Frame::Bye => "BYE",
        Frame::ByeAck => "BYE_ACK",
    }
}

/// Map an I/O failure on the wire to the typed protocol error.
pub(crate) fn io_protocol(e: std::io::Error) -> Error {
    Error::Protocol(format!("io: {e}"))
}

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// `u8 kind` (0 = none/raw), then for kind ≠ 0 the two spec parameters
/// as `f64` bits — 1 byte on the raw path, 17 on the shaped one.
fn put_dist(buf: &mut Vec<u8>, d: Option<DistSpec>) {
    match d {
        None => buf.push(0),
        Some(spec) => {
            let (k, a, b) = spec.wire_parts();
            buf.push(k);
            put_u64(buf, a.to_bits());
            put_u64(buf, b.to_bits());
        }
    }
}

fn put_target(buf: &mut Vec<u8>, t: ReqTarget) {
    match t {
        ReqTarget::Stream(s) => {
            buf.push(0);
            put_u64(buf, s);
        }
        ReqTarget::Group(g) => {
            buf.push(1);
            put_u64(buf, g as u64);
        }
    }
}

/// The `(code, a, b, message)` wire form of every [`enum@Error`] variant.
fn put_error(buf: &mut Vec<u8>, e: &Error) {
    let (code, a, b, msg): (u16, u64, u64, &str) = match e {
        Error::LagWindowExceeded { lead, window } => (1, *lead, *window, ""),
        Error::UnknownStream { stream, have } => (2, *stream, *have, ""),
        Error::GroupOutOfRange { group, have } => (3, *group as u64, *have as u64, ""),
        Error::InvalidConfig(m) => (4, 0, 0, m.as_str()),
        Error::Backend(m) => (5, 0, 0, m.as_str()),
        Error::UnknownGenerator { name } => (6, 0, 0, name.as_str()),
        Error::Protocol(m) => (7, 0, 0, m.as_str()),
        Error::Cancelled => (8, 0, 0, ""),
        Error::DeadlineExceeded => (9, 0, 0, ""),
        Error::QuotaExceeded { in_flight, quota } => (10, *in_flight, *quota, ""),
    };
    put_u16(buf, code);
    put_u64(buf, a);
    put_u64(buf, b);
    put_str(buf, msg);
}

/// Counters and gauges as counted `(name, value)` lists, histograms
/// with sparse nonzero buckets (see the module docs for the layout).
fn put_snapshot(buf: &mut Vec<u8>, snap: &StatsSnapshot) {
    put_u32(buf, snap.counters.len() as u32);
    for (name, v) in &snap.counters {
        put_str(buf, name);
        put_u64(buf, *v);
    }
    put_u32(buf, snap.gauges.len() as u32);
    for (name, v) in &snap.gauges {
        put_str(buf, name);
        put_u64(buf, *v);
    }
    put_u32(buf, snap.hists.len() as u32);
    for (name, h) in &snap.hists {
        put_str(buf, name);
        put_u64(buf, h.count);
        put_u64(buf, h.sum);
        let nonzero: Vec<(usize, u64)> = h
            .buckets
            .iter()
            .copied()
            .enumerate()
            .filter(|&(_, c)| c > 0)
            .collect();
        put_u32(buf, nonzero.len() as u32);
        for (k, c) in nonzero {
            buf.push(k as u8);
            put_u64(buf, c);
        }
    }
}

fn decode_error(code: u16, a: u64, b: u64, msg: String) -> Error {
    match code {
        1 => Error::LagWindowExceeded { lead: a, window: b },
        2 => Error::UnknownStream { stream: a, have: b },
        3 => Error::GroupOutOfRange { group: a as usize, have: b as usize },
        4 => Error::InvalidConfig(msg),
        5 => Error::Backend(msg),
        6 => Error::UnknownGenerator { name: msg },
        7 => Error::Protocol(msg),
        8 => Error::Cancelled,
        9 => Error::DeadlineExceeded,
        10 => Error::QuotaExceeded { in_flight: a, quota: b },
        other => Error::Protocol(format!("unknown error code {other} ({msg:?})")),
    }
}

/// Serialize one frame onto `w` (length prefix + payload). Large DATA
/// frames are the serving hot path: the payload is built in one buffer
/// and written with two `write_all`s (callers wrap the socket in a
/// `BufWriter` and flush at reply-batch boundaries).
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<(), Error> {
    let mut p = Vec::with_capacity(32);
    match frame {
        Frame::Hello { version } => {
            p.push(K_HELLO);
            p.extend_from_slice(&MAGIC);
            put_u16(&mut p, *version);
        }
        Frame::Welcome {
            version,
            engine,
            n_streams,
            n_groups,
            group_width,
            chunk_rows,
            max_fill,
        } => {
            p.push(K_WELCOME);
            put_u16(&mut p, *version);
            put_str(&mut p, engine);
            put_u64(&mut p, *n_streams);
            put_u64(&mut p, *n_groups);
            put_u32(&mut p, *group_width);
            put_u32(&mut p, *chunk_rows);
            put_u64(&mut p, *max_fill);
        }
        Frame::Lease { req, target, resume, dist } => {
            p.push(K_LEASE);
            put_u64(&mut p, *req);
            put_target(&mut p, *target);
            p.push(u8::from(resume.is_some()));
            put_u64(&mut p, resume.unwrap_or(0));
            put_dist(&mut p, *dist);
        }
        Frame::Leased { req, h, xs_origin, cursor } => {
            p.push(K_LEASED);
            put_u64(&mut p, *req);
            put_u64(&mut p, *h);
            for x in xs_origin {
                put_u32(&mut p, *x);
            }
            put_u64(&mut p, *cursor);
        }
        Frame::Fill { req, target, rows, repeat, deadline_ms, tag, dist } => {
            p.push(K_FILL);
            put_u64(&mut p, *req);
            put_target(&mut p, *target);
            put_u64(&mut p, *rows);
            put_u32(&mut p, *repeat);
            put_u64(&mut p, *deadline_ms);
            put_u64(&mut p, *tag);
            put_dist(&mut p, *dist);
        }
        Frame::Cancel { req } => {
            p.push(K_CANCEL);
            put_u64(&mut p, *req);
        }
        Frame::Data { req, seq, last, values } => {
            p.reserve(18 + values.len() * 4);
            p.push(K_DATA);
            put_u64(&mut p, *req);
            put_u32(&mut p, *seq);
            p.push(u8::from(*last));
            put_u32(&mut p, values.len() as u32);
            for v in values {
                p.extend_from_slice(&v.to_le_bytes());
            }
        }
        Frame::Err { req, seq, last, error } => {
            p.push(K_ERR);
            put_u64(&mut p, *req);
            put_u32(&mut p, *seq);
            p.push(u8::from(*last));
            put_error(&mut p, error);
        }
        Frame::StatsReq { req, cursor } => {
            p.push(K_STATS_REQ);
            put_u64(&mut p, *req);
            put_u64(&mut p, *cursor);
        }
        Frame::Stats { req, cursor, delta, snap } => {
            p.push(K_STATS);
            put_u64(&mut p, *req);
            put_u64(&mut p, *cursor);
            p.push(u8::from(*delta));
            put_snapshot(&mut p, snap);
        }
        Frame::TraceReq { req } => {
            p.push(K_TRACE_REQ);
            put_u64(&mut p, *req);
        }
        Frame::Trace { req, json } => {
            p.reserve(13 + json.len());
            p.push(K_TRACE);
            put_u64(&mut p, *req);
            put_str(&mut p, json);
        }
        Frame::Bye => p.push(K_BYE),
        Frame::ByeAck => p.push(K_BYE_ACK),
    }
    if p.len() > MAX_FRAME {
        return Err(Error::Protocol(format!("frame too large ({} bytes)", p.len())));
    }
    w.write_all(&(p.len() as u32).to_le_bytes()).map_err(io_protocol)?;
    w.write_all(&p).map_err(io_protocol)?;
    Ok(())
}

/// Read one frame off `r`. `Ok(None)` is a clean close (EOF exactly at a
/// frame boundary); EOF anywhere else, a bad length, or a malformed
/// payload is a typed [`Error::Protocol`].
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>, Error> {
    let mut len4 = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len4[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(Error::Protocol("connection closed mid frame header".into()))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(io_protocol(e)),
        }
    }
    let len = u32::from_le_bytes(len4) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(Error::Protocol(format!("bad frame length {len}")));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            Error::Protocol("connection closed mid frame".into())
        } else {
            io_protocol(e)
        }
    })?;
    decode_frame(&payload).map(Some)
}

/// Cursor over one frame payload; every accessor fails typed on a short
/// payload, and [`Dec::finish`] rejects trailing bytes.
struct Dec<'a> {
    b: &'a [u8],
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], Error> {
        if self.b.len() < n {
            return Err(Error::Protocol("truncated frame".into()));
        }
        let (head, tail) = self.b.split_at(n);
        self.b = tail;
        Ok(head)
    }

    /// Exactly `N` bytes as an array (`take` already failed typed on a
    /// short payload, so the copy length always matches).
    fn word<const N: usize>(&mut self) -> Result<[u8; N], Error> {
        let mut out = [0u8; N];
        out.copy_from_slice(self.take(N)?);
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, Error> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, Error> {
        Ok(u16::from_le_bytes(self.word()?))
    }

    fn u32(&mut self) -> Result<u32, Error> {
        Ok(u32::from_le_bytes(self.word()?))
    }

    fn u64(&mut self) -> Result<u64, Error> {
        Ok(u64::from_le_bytes(self.word()?))
    }

    fn string(&mut self) -> Result<String, Error> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| Error::Protocol("string is not UTF-8".into()))
    }

    fn target(&mut self) -> Result<ReqTarget, Error> {
        match self.u8()? {
            0 => Ok(ReqTarget::Stream(self.u64()?)),
            1 => Ok(ReqTarget::Group(self.u64()? as usize)),
            k => Err(Error::Protocol(format!("unknown target kind {k}"))),
        }
    }

    /// Decode a dist field, validating the parameter domain: an unknown
    /// kind or an out-of-domain/non-finite parameter is a typed
    /// [`Error::Protocol`] — the frame is rejected before the server
    /// allocates anything for the request.
    fn dist(&mut self) -> Result<Option<DistSpec>, Error> {
        match self.u8()? {
            0 => Ok(None),
            k => {
                let a = f64::from_bits(self.u64()?);
                let b = f64::from_bits(self.u64()?);
                DistSpec::from_wire(k, a, b)
                    .map(Some)
                    .map_err(|e| Error::Protocol(format!("bad dist field: {e}")))
            }
        }
    }

    /// Decode a STATS payload's metric families. List lengths are
    /// implicitly bounded by [`MAX_FRAME`] (every element costs bytes),
    /// so a garbage count runs out of payload and fails typed.
    fn snapshot(&mut self) -> Result<StatsSnapshot, Error> {
        let n = self.u32()? as usize;
        let mut counters = Vec::with_capacity(n.min(1024));
        for _ in 0..n {
            let name = self.string()?;
            counters.push((name, self.u64()?));
        }
        let n = self.u32()? as usize;
        let mut gauges = Vec::with_capacity(n.min(1024));
        for _ in 0..n {
            let name = self.string()?;
            gauges.push((name, self.u64()?));
        }
        let n = self.u32()? as usize;
        let mut hists = Vec::with_capacity(n.min(1024));
        for _ in 0..n {
            let name = self.string()?;
            let count = self.u64()?;
            let sum = self.u64()?;
            let mut h = HistSnapshot { buckets: [0; HIST_BUCKETS], count, sum };
            let nb = self.u32()? as usize;
            for _ in 0..nb {
                let k = self.u8()? as usize;
                let c = self.u64()?;
                let slot = h
                    .buckets
                    .get_mut(k)
                    .ok_or_else(|| Error::Protocol(format!("bucket index {k} out of range")))?;
                *slot = c;
            }
            hists.push((name, h));
        }
        Ok(StatsSnapshot { counters, gauges, hists })
    }

    fn finish(self) -> Result<(), Error> {
        if self.b.is_empty() {
            Ok(())
        } else {
            Err(Error::Protocol(format!("{} trailing bytes in frame", self.b.len())))
        }
    }
}

/// Reject the reserved [`CONNECTION_REQ`] sentinel in client-chosen
/// request ids (LEASE/FILL/CANCEL/STATS_REQ/TRACE_REQ): letting it
/// through would corrupt the
/// server's reply routing — its DATA/ERR frames would be
/// indistinguishable from connection-level errors.
fn client_req(req: u64) -> Result<u64, Error> {
    if req == CONNECTION_REQ {
        return Err(Error::Protocol(format!(
            "request id {req} is reserved for connection-level errors"
        )));
    }
    Ok(req)
}

/// Decode one frame payload (the bytes after the length prefix).
pub(crate) fn decode_frame(payload: &[u8]) -> Result<Frame, Error> {
    let mut d = Dec { b: payload };
    let frame = match d.u8()? {
        K_HELLO => {
            if d.take(4)? != &MAGIC[..] {
                return Err(Error::Protocol("bad connection magic".into()));
            }
            Frame::Hello { version: d.u16()? }
        }
        K_WELCOME => Frame::Welcome {
            version: d.u16()?,
            engine: d.string()?,
            n_streams: d.u64()?,
            n_groups: d.u64()?,
            group_width: d.u32()?,
            chunk_rows: d.u32()?,
            max_fill: d.u64()?,
        },
        K_LEASE => {
            let req = client_req(d.u64()?)?;
            let target = d.target()?;
            let resume = match (d.u8()?, d.u64()?) {
                (0, 0) => None,
                (0, c) => {
                    return Err(Error::Protocol(format!(
                        "plain LEASE carries cursor {c}"
                    )))
                }
                (1, c) => Some(c),
                (k, _) => return Err(Error::Protocol(format!("unknown resume kind {k}"))),
            };
            Frame::Lease { req, target, resume, dist: d.dist()? }
        }
        K_LEASED => {
            let req = d.u64()?;
            let h = d.u64()?;
            let mut xs_origin = [0u32; 4];
            for x in &mut xs_origin {
                *x = d.u32()?;
            }
            let cursor = d.u64()?;
            Frame::Leased { req, h, xs_origin, cursor }
        }
        K_FILL => Frame::Fill {
            req: client_req(d.u64()?)?,
            target: d.target()?,
            rows: d.u64()?,
            repeat: d.u32()?,
            deadline_ms: d.u64()?,
            tag: d.u64()?,
            dist: d.dist()?,
        },
        K_CANCEL => Frame::Cancel { req: client_req(d.u64()?)? },
        K_DATA => {
            let req = d.u64()?;
            let seq = d.u32()?;
            let last = d.u8()? != 0;
            let count = d.u32()? as usize;
            let bytes = d.take(
                count
                    .checked_mul(4)
                    .ok_or_else(|| Error::Protocol("value count overflow".into()))?,
            )?;
            let values = bytes
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Frame::Data { req, seq, last, values }
        }
        K_ERR => {
            let req = d.u64()?;
            let seq = d.u32()?;
            let last = d.u8()? != 0;
            let code = d.u16()?;
            let a = d.u64()?;
            let b = d.u64()?;
            let msg = d.string()?;
            Frame::Err { req, seq, last, error: decode_error(code, a, b, msg) }
        }
        K_STATS_REQ => Frame::StatsReq { req: client_req(d.u64()?)?, cursor: d.u64()? },
        K_STATS => Frame::Stats {
            req: d.u64()?,
            cursor: d.u64()?,
            delta: d.u8()? != 0,
            snap: d.snapshot()?,
        },
        K_TRACE_REQ => Frame::TraceReq { req: client_req(d.u64()?)? },
        K_TRACE => Frame::Trace { req: d.u64()?, json: d.string()? },
        K_BYE => Frame::Bye,
        K_BYE_ACK => Frame::ByeAck,
        k => return Err(Error::Protocol(format!("unknown frame kind {k}"))),
    };
    d.finish()?;
    Ok(frame)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(frame: Frame) {
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        let mut r = &buf[..];
        let back = read_frame(&mut r).unwrap().expect("one frame");
        assert_eq!(back, frame);
        assert!(r.is_empty(), "no bytes left over");
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF after the frame");
    }

    #[test]
    fn every_frame_roundtrips() {
        roundtrip(Frame::Hello { version: VERSION });
        roundtrip(Frame::Welcome {
            version: VERSION,
            engine: "sharded".into(),
            n_streams: 1 << 20,
            n_groups: 1 << 14,
            group_width: 64,
            chunk_rows: 1024,
            max_fill: 1 << 22,
        });
        roundtrip(Frame::Lease {
            req: 7,
            target: ReqTarget::Stream(42),
            resume: None,
            dist: None,
        });
        roundtrip(Frame::Lease {
            req: 8,
            target: ReqTarget::Group(3),
            resume: Some(0),
            dist: None,
        });
        roundtrip(Frame::Lease {
            req: 11,
            target: ReqTarget::Group(3),
            resume: Some(1 << 40),
            dist: Some(DistSpec::Normal { mean: -1.25, std: 0.5 }),
        });
        roundtrip(Frame::Leased {
            req: 7,
            h: 0xdead_beef,
            xs_origin: [1, 2, 3, 4],
            cursor: 0,
        });
        roundtrip(Frame::Leased { req: 8, h: 0, xs_origin: [0; 4], cursor: 123_456 });
        roundtrip(Frame::Fill {
            req: 9,
            target: ReqTarget::Group(5),
            rows: 1024,
            repeat: 16,
            deadline_ms: 0,
            tag: 0,
            dist: None,
        });
        roundtrip(Frame::Fill {
            req: 10,
            target: ReqTarget::Stream(3),
            rows: 64,
            repeat: 2,
            deadline_ms: 2_500,
            tag: 7,
            dist: None,
        });
        for spec in [
            DistSpec::Uniform01,
            DistSpec::UniformRange { lo: -2.0, hi: 3.0 },
            DistSpec::Normal { mean: 0.0, std: 1.0 },
            DistSpec::Exponential { rate: 1.5 },
            DistSpec::Bernoulli { p: 0.25 },
            DistSpec::Poisson { rate: 40.0 },
        ] {
            roundtrip(Frame::Fill {
                req: 12,
                target: ReqTarget::Group(1),
                rows: 256,
                repeat: 4,
                deadline_ms: 0,
                tag: 3,
                dist: Some(spec),
            });
        }
        roundtrip(Frame::Cancel { req: 9 });
        roundtrip(Frame::StatsReq { req: 13, cursor: 0 });
        roundtrip(Frame::StatsReq { req: 14, cursor: 77 });
        roundtrip(Frame::Stats {
            req: 13,
            cursor: 78,
            delta: true,
            snap: StatsSnapshot::default(),
        });
        let hist = HistSnapshot {
            buckets: std::array::from_fn(|k| u64::from(matches!(k, 10 | 11 | 63))),
            count: 3,
            sum: 900 + 1100 + u64::MAX / 2,
        };
        roundtrip(Frame::Stats {
            req: 13,
            cursor: 79,
            delta: false,
            snap: StatsSnapshot {
                counters: vec![
                    ("serve.frames_in".into(), 42),
                    ("serve.rejects.quota".into(), u64::MAX),
                ],
                gauges: vec![("serve.outbox_depth".into(), 7)],
                hists: vec![("serve.submit_deliver_ns".into(), hist)],
            },
        });
        roundtrip(Frame::TraceReq { req: 15 });
        roundtrip(Frame::Trace { req: 15, json: "{\"traceEvents\":[]}".into() });
        roundtrip(Frame::Data { req: 9, seq: 3, last: false, values: vec![] });
        roundtrip(Frame::Data {
            req: 9,
            seq: 15,
            last: true,
            values: (0..1000u32).map(|i| i.wrapping_mul(2654435761)).collect(),
        });
        roundtrip(Frame::Bye);
        roundtrip(Frame::ByeAck);
    }

    #[test]
    fn every_error_variant_crosses_the_wire_typed() {
        for e in [
            Error::LagWindowExceeded { lead: 99, window: 64 },
            Error::UnknownStream { stream: 8, have: 8 },
            Error::GroupOutOfRange { group: 2, have: 2 },
            Error::InvalidConfig("zero streams".into()),
            Error::Backend("shard 3 is gone".into()),
            Error::UnknownGenerator { name: "WELL".into() },
            Error::Protocol("short read".into()),
            Error::Cancelled,
            Error::DeadlineExceeded,
            Error::QuotaExceeded { in_flight: 65, quota: 64 },
        ] {
            let retryable = e.is_retryable();
            let mut buf = Vec::new();
            write_frame(&mut buf, &Frame::Err { req: 1, seq: 0, last: true, error: e.clone() })
                .unwrap();
            match read_frame(&mut &buf[..]).unwrap().unwrap() {
                Frame::Err { error, .. } => {
                    assert_eq!(error, e);
                    assert_eq!(error.is_retryable(), retryable, "{error}");
                }
                other => panic!("expected ERR, got {other:?}"),
            }
        }
    }

    #[test]
    fn truncations_are_typed_protocol_errors() {
        let mut buf = Vec::new();
        write_frame(
            &mut buf,
            &Frame::Data { req: 1, seq: 0, last: true, values: vec![1, 2, 3] },
        )
        .unwrap();
        // Every proper prefix must fail typed (mid-header, mid-payload),
        // except the empty one (clean EOF).
        for cut in 1..buf.len() {
            let err = read_frame(&mut &buf[..cut]).expect_err("truncated frame must fail");
            assert!(matches!(err, Error::Protocol(_)), "cut {cut}: {err}");
        }
        assert!(read_frame(&mut &buf[..0]).unwrap().is_none());
    }

    #[test]
    fn garbage_is_rejected_before_allocation() {
        // An absurd length prefix must be rejected without reserving it.
        let huge = ((MAX_FRAME + 1) as u32).to_le_bytes();
        assert!(matches!(read_frame(&mut &huge[..]), Err(Error::Protocol(_))));
        // Zero-length frames carry no kind byte.
        let zero = 0u32.to_le_bytes();
        assert!(matches!(read_frame(&mut &zero[..]), Err(Error::Protocol(_))));
        // Unknown kind, bad magic, trailing bytes.
        assert!(matches!(decode_frame(&[200]), Err(Error::Protocol(_))));
        assert!(matches!(
            decode_frame(&[K_HELLO, b'X', b'X', b'X', b'X', 1, 0]),
            Err(Error::Protocol(_))
        ));
        assert!(matches!(decode_frame(&[K_BYE, 0xff]), Err(Error::Protocol(_))));
    }

    #[test]
    fn reserved_req_id_is_rejected_at_decode_time() {
        // CONNECTION_REQ is the server's connection-level sentinel; a
        // client frame carrying it must fail typed, not corrupt routing.
        for frame in [
            Frame::Lease {
                req: CONNECTION_REQ,
                target: ReqTarget::Stream(0),
                resume: None,
                dist: None,
            },
            Frame::Fill {
                req: CONNECTION_REQ,
                target: ReqTarget::Group(0),
                rows: 1,
                repeat: 1,
                deadline_ms: 0,
                tag: 0,
                dist: None,
            },
            Frame::Cancel { req: CONNECTION_REQ },
            Frame::StatsReq { req: CONNECTION_REQ, cursor: 0 },
            Frame::TraceReq { req: CONNECTION_REQ },
        ] {
            let mut buf = Vec::new();
            write_frame(&mut buf, &frame).unwrap();
            let err = read_frame(&mut &buf[..]).expect_err("reserved req id must fail");
            assert!(matches!(err, Error::Protocol(_)), "{err}");
            assert!(format!("{err}").contains("reserved"), "{err}");
        }
        // The sentinel stays legal where the *server* speaks it.
        let mut buf = Vec::new();
        write_frame(
            &mut buf,
            &Frame::Err {
                req: CONNECTION_REQ,
                seq: 0,
                last: true,
                error: Error::Protocol("bad frame".into()),
            },
        )
        .unwrap();
        assert!(matches!(read_frame(&mut &buf[..]).unwrap(), Some(Frame::Err { .. })));
    }

    #[test]
    fn out_of_domain_dist_params_are_rejected_typed_at_decode() {
        // The encoder doesn't validate (it writes whatever the struct
        // holds), so these produce byte-exact malicious frames; the
        // decoder must reject each typed — before any allocation for
        // the fill — rather than admit an unshapeable spec.
        for bad in [
            DistSpec::Bernoulli { p: 1.5 },
            DistSpec::Bernoulli { p: -0.5 },
            DistSpec::Exponential { rate: 0.0 },
            DistSpec::Exponential { rate: -1.0 },
            DistSpec::Exponential { rate: f64::NAN },
            DistSpec::Normal { mean: 0.0, std: -1.0 },
            DistSpec::Normal { mean: f64::INFINITY, std: 1.0 },
            DistSpec::UniformRange { lo: 2.0, hi: 1.0 },
            DistSpec::Poisson { rate: 1e9 },
        ] {
            for frame in [
                Frame::Fill {
                    req: 1,
                    target: ReqTarget::Group(0),
                    rows: 8,
                    repeat: 1,
                    deadline_ms: 0,
                    tag: 0,
                    dist: Some(bad),
                },
                Frame::Lease {
                    req: 1,
                    target: ReqTarget::Group(0),
                    resume: Some(0),
                    dist: Some(bad),
                },
            ] {
                let mut buf = Vec::new();
                write_frame(&mut buf, &frame).unwrap();
                let err = read_frame(&mut &buf[..]).expect_err("bad dist must fail");
                assert!(matches!(err, Error::Protocol(_)), "{bad:?}: {err}");
            }
        }
        // Unknown dist kind: frame bytes with kind 9 after a valid FILL.
        let mut p = vec![K_FILL];
        p.extend_from_slice(&1u64.to_le_bytes()); // req
        p.push(1); // target kind: group
        p.extend_from_slice(&0u64.to_le_bytes());
        p.extend_from_slice(&8u64.to_le_bytes()); // rows
        p.extend_from_slice(&1u32.to_le_bytes()); // repeat
        p.extend_from_slice(&0u64.to_le_bytes()); // deadline_ms
        p.extend_from_slice(&0u64.to_le_bytes()); // tag
        p.push(9); // dist kind: unknown
        p.extend_from_slice(&0u64.to_le_bytes());
        p.extend_from_slice(&0u64.to_le_bytes());
        assert!(matches!(decode_frame(&p), Err(Error::Protocol(_))));
    }

    #[test]
    fn stats_bucket_index_out_of_range_is_rejected() {
        // A STATS histogram entry claiming log2 bucket 64 (only 0..=63
        // exist) must fail typed instead of indexing out of bounds.
        let mut p = vec![K_STATS];
        p.extend_from_slice(&1u64.to_le_bytes()); // req
        p.extend_from_slice(&2u64.to_le_bytes()); // cursor
        p.push(0); // delta
        p.extend_from_slice(&0u32.to_le_bytes()); // no counters
        p.extend_from_slice(&0u32.to_le_bytes()); // no gauges
        p.extend_from_slice(&1u32.to_le_bytes()); // one hist
        p.extend_from_slice(&1u32.to_le_bytes()); // name "h"
        p.push(b'h');
        p.extend_from_slice(&1u64.to_le_bytes()); // count
        p.extend_from_slice(&5u64.to_le_bytes()); // sum
        p.extend_from_slice(&1u32.to_le_bytes()); // one bucket entry
        p.push(64); // index out of range
        p.extend_from_slice(&1u64.to_le_bytes());
        let err = decode_frame(&p).expect_err("bucket 64 must fail");
        assert!(matches!(err, Error::Protocol(_)), "{err}");
        assert!(format!("{err}").contains("bucket index 64"), "{err}");
    }

    #[test]
    fn plain_lease_with_cursor_is_rejected() {
        // resume kind 0 must carry cursor 0 — anything else is a
        // malformed frame, not silently ignored state.
        let mut p = vec![K_LEASE];
        p.extend_from_slice(&7u64.to_le_bytes());
        p.push(0); // target kind: stream
        p.extend_from_slice(&3u64.to_le_bytes());
        p.push(0); // resume kind: plain
        p.extend_from_slice(&99u64.to_le_bytes()); // …but a cursor anyway
        assert!(matches!(decode_frame(&p), Err(Error::Protocol(_))));
    }

    #[test]
    fn frames_stream_back_to_back() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Hello { version: VERSION }).unwrap();
        write_frame(&mut buf, &Frame::Bye).unwrap();
        let mut r = &buf[..];
        assert!(matches!(read_frame(&mut r).unwrap(), Some(Frame::Hello { .. })));
        assert!(matches!(read_frame(&mut r).unwrap(), Some(Frame::Bye)));
        assert!(read_frame(&mut r).unwrap().is_none());
    }
}
