//! Reusable load generator: hammer a serving endpoint from N concurrent
//! connections and report delivered GRN/s plus per-fill latency
//! percentiles — the client half of the `serve`/`loadgen` CLI pair, the
//! serve benchmark row, and the CI loopback smoke test.
//!
//! Each connection leases one group (round-robin over the server's
//! groups) and drains its share through a sequence of chunked FILLs
//! (so the server pipelines `window` sub-requests per session and every
//! fill yields one latency sample), verifying exactly-once in-order
//! delivery as it goes: chunk seqs must arrive as exactly `0..repeat`
//! with `last` on the final chunk and every delivered chunk full-size —
//! a lost, duplicated, or reordered sub-request fails the run with a
//! typed error.
//!
//! The lifecycle knobs exercise the request-lifecycle API end to end:
//! [`LoadgenConfig::deadline_ms`] puts a deadline on every FILL
//! (sub-requests the server cannot start in time come back as typed
//! `DeadlineExceeded` chunks, counted in the report), and
//! [`LoadgenConfig::cancel_storm`] cancels every second fill right
//! after submitting it — the delivered chunks of a cancelled fill must
//! still be a contiguous, bit-exact prefix followed only by `Cancelled`
//! chunks, and the server must tear every session down cleanly.

use std::time::{Duration, Instant};

use crate::coordinator::{ReqTarget, Request};
use crate::dist::DistSpec;
use crate::error::Error;
use crate::serve::client::RemoteClient;
use crate::util::bench;

/// What to throw at the server.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Endpoint to hammer (`host:port`).
    pub addr: String,
    /// Concurrent connections (each is one server session). Default 8.
    pub connections: usize,
    /// Numbers each connection drains (rounded up to whole sub-fills).
    /// Default 2²².
    pub numbers_per_conn: u64,
    /// Rows per sub-request; 0 (default) uses the server's advertised
    /// chunk hint. Clamped so one sub-request fits the server's
    /// `max_fill`.
    pub chunk_rows: u32,
    /// Sequential FILLs each connection splits its share across — each
    /// is one latency sample for the report's percentiles. Default 8.
    pub fills_per_conn: u32,
    /// Deadline carried on every FILL, in milliseconds (0 = none).
    /// Sub-requests the server cannot start in time resolve as typed
    /// retryable `DeadlineExceeded` chunks, tallied in
    /// [`LoadgenReport::expired_chunks`].
    pub deadline_ms: u64,
    /// Cancel every second fill immediately after submitting it (the
    /// cancel-storm smoke): its delivered chunks must stay a
    /// contiguous prefix, the rest arriving as `Cancelled` chunks
    /// (tallied in [`LoadgenReport::cancelled_chunks`]).
    pub cancel_storm: bool,
    /// Connect attempts per connection before the failure surfaces
    /// typed — the server may still be binding when loadgen starts (the
    /// CI smoke test races them), but a misconfigured endpoint must
    /// fail loudly instead of retrying forever. Default 100.
    pub connect_attempts: u32,
    /// Pause between connect attempts. Default 100 ms.
    pub connect_backoff: Duration,
    /// QoS tags assigned round-robin across connections; every FILL a
    /// connection submits carries its tag, so the server fair-drains
    /// and quota-checks the load per tenant class. Empty (the default)
    /// puts every fill on tag 0.
    pub tags: Vec<u64>,
    /// Shape every fill through this distribution (`None` = raw words).
    /// Delivered chunks then carry the [`crate::dist`] payload encoding
    /// and [`LoadgenReport::numbers`] counts payload words; chunk sizing
    /// accounts for the spec's raw-draw amplification so every
    /// sub-request still fits the server's `max_fill`.
    pub dist: Option<DistSpec>,
    /// After the run, pull the server's own STATS snapshot over one
    /// extra connection into [`LoadgenReport::server_stats`], so the
    /// CLI can print server-side submit→deliver percentiles next to
    /// the client-side ones (any gap between the two is wire/client
    /// overhead, not engine time).
    pub stats: bool,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7777".into(),
            connections: 8,
            numbers_per_conn: 1 << 22,
            chunk_rows: 0,
            fills_per_conn: 8,
            deadline_ms: 0,
            cancel_storm: false,
            connect_attempts: 100,
            connect_backoff: Duration::from_millis(100),
            tags: Vec::new(),
            dist: None,
            stats: false,
        }
    }
}

/// What came back.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    /// Connections that ran (== sessions the server served).
    pub connections: usize,
    /// Numbers delivered across all connections, verified exactly-once.
    pub numbers: u64,
    /// Sub-request chunks delivered with data.
    pub chunks: u64,
    /// Chunks resolved as typed `Cancelled` errors (cancel storm).
    pub cancelled_chunks: u64,
    /// Chunks resolved as typed `DeadlineExceeded` errors.
    pub expired_chunks: u64,
    /// Wall-clock seconds, connect to last BYE_ACK.
    pub seconds: f64,
    /// Per-fill service latency samples in seconds (submit → final
    /// chunk), one per fully-serviced fill; cancelled and expired
    /// fills are excluded so the percentiles describe served work,
    /// not time-to-fail-fast.
    pub fill_latencies_s: Vec<f64>,
    /// The server's own STATS snapshot, pulled over one extra
    /// connection after the run when [`LoadgenConfig::stats`] is set.
    pub server_stats: Option<crate::obs::StatsSnapshot>,
}

impl LoadgenReport {
    /// Delivered giga-random-numbers per second (the paper's GRN/s).
    pub fn grn_per_s(&self) -> f64 {
        self.numbers as f64 / self.seconds / 1e9
    }

    /// A per-fill latency percentile in seconds (`NaN` with no
    /// samples) — `p50`/`p95`/`p99` are what the CLI and the bench
    /// report.
    pub fn latency_percentile(&self, pct: f64) -> f64 {
        bench::percentile(&self.fill_latencies_s, pct)
    }
}

/// Dial with a bounded retry schedule: `attempts` tries, `backoff`
/// apart. The final failure surfaces typed, naming the schedule, so a
/// dead endpoint is a loud error — not an unbounded sleep loop.
pub(crate) fn connect_retry(
    addr: &str,
    attempts: u32,
    backoff: Duration,
) -> Result<RemoteClient, Error> {
    let attempts = attempts.max(1);
    let mut last = None;
    for i in 0..attempts {
        if i > 0 {
            std::thread::sleep(backoff);
        }
        match RemoteClient::connect(addr) {
            Ok(client) => return Ok(client),
            Err(e) => last = Some(e),
        }
    }
    let detail = last.map(|e| format!(": {e}")).unwrap_or_default();
    Err(Error::Protocol(format!(
        "could not connect to {addr} after {attempts} attempts ({backoff:?} apart){detail}"
    )))
}

/// What one connection tallied.
struct ConnResult {
    numbers: u64,
    chunks: u64,
    cancelled: u64,
    expired: u64,
    latencies_s: Vec<f64>,
}

/// Drive one connection: lease its group, run `fills` sequential
/// chunked FILLs (cancelling every second one under the storm), verify
/// ordering/shape, tally outcomes.
#[allow(clippy::too_many_arguments)]
fn run_conn(
    client: &RemoteClient,
    cfg: &LoadgenConfig,
    group: usize,
    tag: u64,
    chunk_rows: u64,
    per_chunk: u64,
    fills: u32,
    repeat: u32,
) -> Result<ConnResult, Error> {
    client.lease(ReqTarget::Group(group))?;
    let request = Request::group(group)
        .rows(chunk_rows as usize)
        .deadline_opt((cfg.deadline_ms > 0).then(|| Duration::from_millis(cfg.deadline_ms)))
        .tag(tag)
        .dist_opt(cfg.dist);
    let mut out = ConnResult {
        numbers: 0,
        chunks: 0,
        cancelled: 0,
        expired: 0,
        latencies_s: Vec::with_capacity(fills as usize),
    };
    for fill_idx in 0..fills {
        let storm_cancel = cfg.cancel_storm && fill_idx % 2 == 1;
        let t_fill = Instant::now();
        let req = client.submit_fill(&request, repeat)?;
        if storm_cancel {
            client.cancel(req)?;
        }
        let mut fill_cancelled = 0u64;
        let mut fill_expired = 0u64;
        for expect_seq in 0..repeat {
            let chunk = client.next_chunk(req)?;
            if chunk.seq != expect_seq {
                return Err(Error::Protocol(format!(
                    "chunk seq {} delivered where {expect_seq} was due \
                     (lost, duplicated, or reordered sub-request)",
                    chunk.seq
                )));
            }
            if chunk.last != (expect_seq + 1 == repeat) {
                return Err(Error::Protocol(format!(
                    "last-chunk flag out of place at seq {expect_seq}"
                )));
            }
            match chunk.result {
                Ok(values) => {
                    if fill_cancelled > 0 {
                        // The atomic server-side cancel sweep guarantees
                        // the delivered chunks form a contiguous prefix.
                        return Err(Error::Protocol(format!(
                            "DATA chunk at seq {expect_seq} after a Cancelled chunk \
                             (cancelled fill delivered a non-contiguous prefix)"
                        )));
                    }
                    if values.len() as u64 != per_chunk {
                        return Err(Error::Protocol(format!(
                            "chunk of {} numbers where {per_chunk} were due",
                            values.len()
                        )));
                    }
                    out.numbers += values.len() as u64;
                    out.chunks += 1;
                }
                Err(Error::Cancelled) if storm_cancel => {
                    fill_cancelled += 1;
                }
                Err(Error::DeadlineExceeded) if cfg.deadline_ms > 0 => {
                    fill_expired += 1;
                }
                Err(e) => return Err(e),
            }
        }
        out.cancelled += fill_cancelled;
        out.expired += fill_expired;
        // Only fully-serviced fills are latency samples: a cancelled or
        // expired fill measures time-to-fail-fast, and folding that in
        // would understate the served-work percentiles exactly when the
        // deadline bites.
        if fill_cancelled == 0 && fill_expired == 0 {
            out.latencies_s.push(t_fill.elapsed().as_secs_f64());
        }
    }
    Ok(out)
}

/// Run the load and verify exactly-once delivery (see the module docs).
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport, Error> {
    if cfg.connections == 0 {
        return Err(Error::InvalidConfig("loadgen needs at least one connection".into()));
    }
    // The first connection doubles as the endpoint probe (with retries)
    // and tells us the serving shape.
    let first = connect_retry(&cfg.addr, cfg.connect_attempts, cfg.connect_backoff)?;
    let info = first.info().clone();
    if info.n_groups == 0 {
        return Err(Error::InvalidConfig("server serves no groups".into()));
    }
    if let Some(spec) = cfg.dist {
        spec.validate()?;
    }
    let lane_width = u64::from(info.group_width).max(1);
    // Rows are bounded by whichever is larger per row: the shaped
    // payload (words_per_sample) or the raw draws feeding it
    // (draws_per_row) — both must fit one max_fill sub-request.
    let per_row_cost = lane_width
        * cfg.dist.map_or(1, |d| d.words_per_sample().max(d.draws_per_row()) as u64);
    let width = lane_width * cfg.dist.map_or(1, |d| d.words_per_sample() as u64);
    let hint = if cfg.chunk_rows == 0 { info.chunk_rows } else { cfg.chunk_rows };
    let chunk_rows = u64::from(hint).clamp(1, (info.max_fill / per_row_cost).max(1));
    let per_chunk = chunk_rows * width;
    let fills = cfg.fills_per_conn.max(1);
    let repeat: u32 = cfg
        .numbers_per_conn
        .div_ceil(per_chunk.saturating_mul(u64::from(fills)))
        .max(1)
        .try_into()
        .map_err(|_| {
            Error::InvalidConfig(
                "workload needs more than 2^32 chunks per fill; raise chunk_rows or fills"
                    .into(),
            )
        })?;

    let info = &info;
    let mut first = Some(first);
    let t0 = Instant::now();
    let results: Vec<Result<ConnResult, Error>> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for i in 0..cfg.connections {
            let pre = first.take();
            handles.push(s.spawn(move || -> Result<ConnResult, Error> {
                let client = match pre {
                    Some(client) => client,
                    None => {
                        connect_retry(&cfg.addr, cfg.connect_attempts, cfg.connect_backoff)?
                    }
                };
                let group = (i as u64 % info.n_groups) as usize;
                let tag = if cfg.tags.is_empty() { 0 } else { cfg.tags[i % cfg.tags.len()] };
                let out =
                    run_conn(&client, cfg, group, tag, chunk_rows, per_chunk, fills, repeat)?;
                client.bye()?;
                Ok(out)
            }));
        }
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(Error::Backend("loadgen worker panicked".into())))
            })
            .collect()
    });
    let seconds = t0.elapsed().as_secs_f64();

    let mut report = LoadgenReport {
        connections: cfg.connections,
        numbers: 0,
        chunks: 0,
        cancelled_chunks: 0,
        expired_chunks: 0,
        seconds,
        fill_latencies_s: Vec::new(),
        server_stats: None,
    };
    for r in results {
        let c = r?;
        report.numbers += c.numbers;
        report.chunks += c.chunks;
        report.cancelled_chunks += c.cancelled;
        report.expired_chunks += c.expired;
        report.fill_latencies_s.extend(c.latencies_s);
    }
    if cfg.stats {
        // One extra session, after the load has drained, so the
        // snapshot covers the whole run and costs it nothing.
        let probe = connect_retry(&cfg.addr, cfg.connect_attempts, cfg.connect_backoff)?;
        report.server_stats = Some(probe.stats(0)?.snap);
        probe.bye()?;
    }
    Ok(report)
}
