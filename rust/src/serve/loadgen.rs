//! Reusable load generator: hammer a serving endpoint from N concurrent
//! connections and report delivered GRN/s — the client half of the
//! `serve`/`loadgen` CLI pair, the serve benchmark row, and the CI
//! loopback smoke test.
//!
//! Each connection leases one group (round-robin over the server's
//! groups), drains its share through a single chunked FILL (so the
//! server pipelines `window` sub-requests per session), and verifies
//! exactly-once in-order delivery as it goes: chunk seqs must arrive as
//! exactly `0..repeat` with `last` on the final chunk and every chunk
//! full-size — a lost, duplicated, or reordered sub-request fails the
//! run with a typed error.

use std::time::{Duration, Instant};

use crate::coordinator::ReqTarget;
use crate::error::Error;
use crate::serve::client::RemoteClient;

/// What to throw at the server.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Endpoint to hammer (`host:port`).
    pub addr: String,
    /// Concurrent connections (each is one server session). Default 8.
    pub connections: usize,
    /// Numbers each connection drains (rounded up to whole sub-fills).
    /// Default 2²².
    pub numbers_per_conn: u64,
    /// Rows per sub-request; 0 (default) uses the server's advertised
    /// chunk hint. Clamped so one sub-request fits the server's
    /// `max_fill`.
    pub chunk_rows: u32,
    /// Total budget for connect retries — the server may still be
    /// binding when loadgen starts (the CI smoke test races them).
    /// Default 10 s.
    pub connect_budget: Duration,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7777".into(),
            connections: 8,
            numbers_per_conn: 1 << 22,
            chunk_rows: 0,
            connect_budget: Duration::from_secs(10),
        }
    }
}

/// What came back.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    /// Connections that ran (== sessions the server served).
    pub connections: usize,
    /// Numbers delivered across all connections, verified exactly-once.
    pub numbers: u64,
    /// Sub-request chunks delivered.
    pub chunks: u64,
    /// Wall-clock seconds, connect to last BYE_ACK.
    pub seconds: f64,
}

impl LoadgenReport {
    /// Delivered giga-random-numbers per second (the paper's GRN/s).
    pub fn grn_per_s(&self) -> f64 {
        self.numbers as f64 / self.seconds / 1e9
    }
}

fn connect_retry(addr: &str, budget: Duration) -> Result<RemoteClient, Error> {
    let t0 = Instant::now();
    loop {
        match RemoteClient::connect(addr) {
            Ok(client) => return Ok(client),
            Err(e) => {
                if t0.elapsed() >= budget {
                    return Err(e);
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

/// Run the load and verify exactly-once delivery (see the module docs).
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport, Error> {
    if cfg.connections == 0 {
        return Err(Error::InvalidConfig("loadgen needs at least one connection".into()));
    }
    // The first connection doubles as the endpoint probe (with retries)
    // and tells us the serving shape.
    let first = connect_retry(&cfg.addr, cfg.connect_budget)?;
    let info = first.info().clone();
    if info.n_groups == 0 {
        return Err(Error::InvalidConfig("server serves no groups".into()));
    }
    let width = u64::from(info.group_width).max(1);
    let hint = if cfg.chunk_rows == 0 { info.chunk_rows } else { cfg.chunk_rows };
    let chunk_rows = u64::from(hint).clamp(1, (info.max_fill / width).max(1));
    let per_chunk = chunk_rows * width;
    let repeat: u32 = cfg
        .numbers_per_conn
        .div_ceil(per_chunk)
        .max(1)
        .try_into()
        .map_err(|_| {
            Error::InvalidConfig(
                "workload needs more than 2^32 chunks per connection; raise chunk_rows"
                    .into(),
            )
        })?;

    let info = &info;
    let mut first = Some(first);
    let t0 = Instant::now();
    let results: Vec<Result<(u64, u64), Error>> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for i in 0..cfg.connections {
            let pre = first.take();
            handles.push(s.spawn(move || -> Result<(u64, u64), Error> {
                let mut client = match pre {
                    Some(client) => client,
                    None => connect_retry(&cfg.addr, cfg.connect_budget)?,
                };
                let group = (i as u64 % info.n_groups) as usize;
                client.lease(ReqTarget::Group(group))?;
                let req = client.submit_fill(ReqTarget::Group(group), chunk_rows, repeat)?;
                let mut numbers = 0u64;
                for expect_seq in 0..repeat {
                    let chunk = client.next_chunk(req)?;
                    if chunk.seq != expect_seq {
                        return Err(Error::Protocol(format!(
                            "chunk seq {} delivered where {expect_seq} was due \
                             (lost, duplicated, or reordered sub-request)",
                            chunk.seq
                        )));
                    }
                    if chunk.last != (expect_seq + 1 == repeat) {
                        return Err(Error::Protocol(format!(
                            "last-chunk flag out of place at seq {expect_seq}"
                        )));
                    }
                    let values = chunk.result?;
                    if values.len() as u64 != per_chunk {
                        return Err(Error::Protocol(format!(
                            "chunk of {} numbers where {per_chunk} were due",
                            values.len()
                        )));
                    }
                    numbers += values.len() as u64;
                }
                client.bye()?;
                Ok((numbers, u64::from(repeat)))
            }));
        }
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(Error::Backend("loadgen worker panicked".into())))
            })
            .collect()
    });
    let seconds = t0.elapsed().as_secs_f64();

    let mut numbers = 0u64;
    let mut chunks = 0u64;
    for r in results {
        let (n, c) = r?;
        numbers += n;
        chunks += c;
    }
    Ok(LoadgenReport { connections: cfg.connections, numbers, chunks, seconds })
}
