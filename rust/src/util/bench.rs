//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Methodology: warmup runs, then `iters` timed runs; reports min / median /
//! mean / p95. Results print in a stable machine-grepable format:
//! `BENCH <name> median=<s> mean=<s> min=<s> p95=<s> [thrpt=<x>/s]`,
//! and can additionally be serialized as a JSON trajectory point
//! ([`JsonReport`], e.g. `BENCH_parallel.json`) so successive PRs can
//! track throughput over time.

use std::collections::BTreeMap;
use std::time::Instant;

// The shared writer's float constructor (non-finite → `null`), under
// the name this module has always used.
use crate::util::json::{num as json_num, Json};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>,
    /// Work items per run, for throughput reporting (0 = no throughput).
    pub items_per_run: u64,
}

impl Measurement {
    pub fn median(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn p95(&self) -> f64 {
        percentile(&self.samples, 95.0)
    }

    /// An arbitrary sample percentile (e.g. `99.0` for the tail the
    /// serving layer's latency reports track).
    pub fn percentile(&self, pct: f64) -> f64 {
        percentile(&self.samples, pct)
    }

    /// Items/second at the median sample.
    pub fn throughput(&self) -> f64 {
        if self.items_per_run == 0 {
            0.0
        } else {
            self.items_per_run as f64 / self.median()
        }
    }

    pub fn report(&self) {
        let mut line = format!(
            "BENCH {} median={} mean={} min={} p95={}",
            self.name,
            crate::util::fmt_duration(self.median()),
            crate::util::fmt_duration(self.mean()),
            crate::util::fmt_duration(self.min()),
            crate::util::fmt_duration(self.p95()),
        );
        if self.items_per_run > 0 {
            line.push_str(&format!(" thrpt={}", crate::util::fmt_rate(self.throughput())));
        }
        println!("{line}");
    }

    /// JSON object form (seconds for the time stats, items/s throughput).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("median_s".to_string(), json_num(self.median()));
        m.insert("mean_s".to_string(), json_num(self.mean()));
        m.insert("min_s".to_string(), json_num(self.min()));
        m.insert("p95_s".to_string(), json_num(self.p95()));
        m.insert("samples".to_string(), json_num(self.samples.len() as f64));
        m.insert("items_per_run".to_string(), json_num(self.items_per_run as f64));
        m.insert("items_per_sec".to_string(), json_num(self.throughput()));
        Json::Obj(m)
    }
}

/// A machine-readable benchmark report: free-form context (host shape,
/// engine parameters, derived ratios) plus a list of measurements.
/// Written as one JSON document — the trajectory-point format consumed by
/// `BENCH_*.json` files.
#[derive(Default)]
pub struct JsonReport {
    context: BTreeMap<String, Json>,
    measurements: Vec<Json>,
}

impl JsonReport {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn context_str(&mut self, key: &str, value: &str) {
        self.context.insert(key.to_string(), Json::Str(value.to_string()));
    }

    pub fn context_num(&mut self, key: &str, value: f64) {
        self.context.insert(key.to_string(), json_num(value));
    }

    pub fn push(&mut self, m: &Measurement) {
        self.measurements.push(m.to_json());
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("context".to_string(), Json::Obj(self.context.clone()));
        m.insert("benches".to_string(), Json::Arr(self.measurements.clone()));
        Json::Obj(m)
    }

    /// Write the report to `path` (single JSON document + newline).
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
    }
}

/// Nearest-rank percentile of a sample set (`NaN` for an empty set) —
/// shared by [`Measurement`] and the serving layer's per-fill latency
/// reports.
pub fn percentile(samples: &[f64], pct: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut v = samples.to_vec();
    // total_cmp, not partial_cmp().unwrap(): a NaN sample (e.g. a
    // zero-duration timer quantization feeding a ratio) must sort (to
    // the end, under the IEEE total order) instead of panicking the
    // whole bench run.
    v.sort_by(f64::total_cmp);
    let idx = ((pct / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// Benchmark runner with fixed warmup/iteration counts.
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup: 3, iters: 10 }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Self { warmup, iters }
    }

    /// Quick-mode harness honoring $BENCH_ITERS.
    pub fn from_env() -> Self {
        let iters = std::env::var("BENCH_ITERS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(10);
        Self { warmup: (iters / 3).max(1), iters }
    }

    /// Time `f` (which performs `items` work items per call).
    pub fn run<F: FnMut()>(&self, name: &str, items: u64, mut f: F) -> Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let m = Measurement { name: name.to_string(), samples, items_per_run: items };
        m.report();
        m
    }
}

/// Guard against the optimizer deleting benchmark bodies.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_ordering() {
        let m = Measurement {
            name: "t".into(),
            samples: vec![3.0, 1.0, 2.0, 5.0, 4.0],
            items_per_run: 10,
        };
        assert_eq!(m.min(), 1.0);
        assert_eq!(m.median(), 3.0);
        assert_eq!(m.mean(), 3.0);
        assert_eq!(m.p95(), 5.0);
        assert_eq!(m.percentile(99.0), 5.0);
        assert_eq!(m.percentile(25.0), 2.0);
        assert!(percentile(&[], 50.0).is_nan(), "empty sample set is NaN");
        assert!((m.throughput() - 10.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn run_collects_samples() {
        let b = Bench::new(1, 5);
        let mut count = 0u64;
        let m = b.run("noop", 1, || {
            count += 1;
        });
        assert_eq!(m.samples.len(), 5);
        assert_eq!(count, 6); // warmup + iters
    }

    #[test]
    fn nan_sample_does_not_panic_and_keeps_the_report_valid() {
        // Regression: percentile() used partial_cmp().unwrap(), so one
        // NaN sample panicked the whole bench run.
        let m = Measurement {
            name: "nan".into(),
            samples: vec![1.0, f64::NAN, 3.0],
            items_per_run: 6,
        };
        assert_eq!(m.min(), 1.0);
        // NaN sorts last under the total order: the median of
        // [1.0, 3.0, NaN] is 3.0, and p95 lands on the NaN itself.
        assert_eq!(m.median(), 3.0);
        assert!(m.p95().is_nan());
        // The JSON document stays parseable (NaN serializes as null).
        let mut rep = JsonReport::new();
        rep.push(&m);
        let back = Json::parse(&rep.to_json().to_string()).unwrap();
        let benches = back.get("benches").unwrap().as_arr().unwrap();
        assert_eq!(benches[0].get("p95_s"), Some(&Json::Null));
        assert_eq!(benches[0].get("median_s").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn json_report_roundtrips() {
        let m = Measurement {
            name: "engine/sharded".into(),
            samples: vec![0.5, 0.25, 1.0],
            items_per_run: 1000,
        };
        let mut rep = JsonReport::new();
        rep.context_str("bench", "parallel");
        rep.context_num("cores", 8.0);
        rep.context_num("bad_ratio", f64::INFINITY); // must not break the doc
        rep.push(&m);
        let text = rep.to_json().to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("context").unwrap().get("cores").unwrap().as_f64(), Some(8.0));
        assert_eq!(back.get("context").unwrap().get("bad_ratio"), Some(&Json::Null));
        let benches = back.get("benches").unwrap().as_arr().unwrap();
        assert_eq!(benches.len(), 1);
        assert_eq!(benches[0].get("name").unwrap().as_str(), Some("engine/sharded"));
        assert_eq!(benches[0].get("median_s").unwrap().as_f64(), Some(0.5));
        assert_eq!(benches[0].get("items_per_sec").unwrap().as_f64(), Some(2000.0));
    }
}
