//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Methodology: warmup runs, then `iters` timed runs; reports min / median /
//! mean / p95. Results print in a stable machine-grepable format:
//! `BENCH <name> median=<s> mean=<s> min=<s> p95=<s> [thrpt=<x>/s]`.

use std::time::Instant;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>,
    /// Work items per run, for throughput reporting (0 = no throughput).
    pub items_per_run: u64,
}

impl Measurement {
    pub fn median(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn p95(&self) -> f64 {
        percentile(&self.samples, 95.0)
    }

    /// Items/second at the median sample.
    pub fn throughput(&self) -> f64 {
        if self.items_per_run == 0 {
            0.0
        } else {
            self.items_per_run as f64 / self.median()
        }
    }

    pub fn report(&self) {
        let mut line = format!(
            "BENCH {} median={} mean={} min={} p95={}",
            self.name,
            crate::util::fmt_duration(self.median()),
            crate::util::fmt_duration(self.mean()),
            crate::util::fmt_duration(self.min()),
            crate::util::fmt_duration(self.p95()),
        );
        if self.items_per_run > 0 {
            line.push_str(&format!(" thrpt={}", crate::util::fmt_rate(self.throughput())));
        }
        println!("{line}");
    }
}

fn percentile(samples: &[f64], pct: f64) -> f64 {
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if v.is_empty() {
        return f64::NAN;
    }
    let idx = ((pct / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// Benchmark runner with fixed warmup/iteration counts.
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup: 3, iters: 10 }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Self { warmup, iters }
    }

    /// Quick-mode harness honoring $BENCH_ITERS.
    pub fn from_env() -> Self {
        let iters = std::env::var("BENCH_ITERS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(10);
        Self { warmup: (iters / 3).max(1), iters }
    }

    /// Time `f` (which performs `items` work items per call).
    pub fn run<F: FnMut()>(&self, name: &str, items: u64, mut f: F) -> Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let m = Measurement { name: name.to_string(), samples, items_per_run: items };
        m.report();
        m
    }
}

/// Guard against the optimizer deleting benchmark bodies.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_ordering() {
        let m = Measurement {
            name: "t".into(),
            samples: vec![3.0, 1.0, 2.0, 5.0, 4.0],
            items_per_run: 10,
        };
        assert_eq!(m.min(), 1.0);
        assert_eq!(m.median(), 3.0);
        assert_eq!(m.mean(), 3.0);
        assert_eq!(m.p95(), 5.0);
        assert!((m.throughput() - 10.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn run_collects_samples() {
        let b = Bench::new(1, 5);
        let mut count = 0u64;
        let m = b.run("noop", 1, || {
            count += 1;
        });
        assert_eq!(m.samples.len(), 5);
        assert_eq!(count, 6); // warmup + iters
    }
}
