//! Small self-contained utilities (the build is offline — no external
//! crates beyond `xla`/`anyhow`, so JSON parsing, CLI parsing, and the
//! bench harness live here).

pub mod bench;
pub mod cli;
pub mod json;
pub mod unit;

/// Format a throughput in numbers/second with an SI suffix.
pub fn fmt_rate(per_sec: f64) -> String {
    if per_sec >= 1e12 {
        format!("{:.2} T/s", per_sec / 1e12)
    } else if per_sec >= 1e9 {
        format!("{:.2} G/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2} M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} K/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.2} /s")
    }
}

/// Format a duration in adaptive units.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_units() {
        assert_eq!(fmt_rate(5.0e12), "5.00 T/s");
        assert_eq!(fmt_rate(2.5e9), "2.50 G/s");
        assert_eq!(fmt_rate(1.0e6), "1.00 M/s");
        assert_eq!(fmt_rate(1500.0), "1.50 K/s");
        assert_eq!(fmt_rate(12.0), "12.00 /s");
    }

    #[test]
    fn duration_units() {
        assert_eq!(fmt_duration(2.0), "2.000 s");
        assert_eq!(fmt_duration(0.002), "2.000 ms");
        assert_eq!(fmt_duration(2e-6), "2.000 µs");
        assert_eq!(fmt_duration(2e-9), "2.0 ns");
    }
}
