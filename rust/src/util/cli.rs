//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw args. `value_options` lists the long options that consume
    /// a value; any other `--name` is treated as a boolean flag.
    pub fn parse(raw: impl Iterator<Item = String>, value_options: &[&'static str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if value_options.contains(&body) {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{body} requires a value"))?;
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => parse_scaled(v)
                .ok_or_else(|| anyhow!("--{name}: cannot parse {v:?} as a count")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => parse_scaled(v)
                .map(|x| x as u64)
                .ok_or_else(|| anyhow!("--{name}: cannot parse {v:?} as a count")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name}: cannot parse {v:?} as a number")),
        }
    }

    /// Per-command audit: error on any option, flag, or extra positional
    /// this command does not take (typo detection — `--straems 64` must
    /// fail loudly, not silently serve the default).
    pub fn expect(
        &self,
        value_opts: &[&str],
        flags: &[&str],
        max_positional: usize,
    ) -> Result<()> {
        for k in self.options.keys() {
            if !value_opts.contains(&k.as_str()) {
                bail!("unknown option --{k} for this command");
            }
        }
        for f in &self.flags {
            if !flags.contains(&f.as_str()) {
                bail!("unknown flag --{f} for this command");
            }
        }
        if self.positional.len() > max_positional {
            bail!("unexpected argument {:?}", self.positional[max_positional]);
        }
        Ok(())
    }
}

/// Parse counts with scale suffixes: `4k`, `16M`, `2G`, `1e9`, `2^20`.
pub fn parse_scaled(s: &str) -> Option<usize> {
    let s = s.trim();
    if let Some(exp) = s.strip_prefix("2^") {
        let e: u32 = exp.parse().ok()?;
        return 1usize.checked_shl(e);
    }
    if let Ok(v) = s.parse::<usize>() {
        return Some(v);
    }
    if let Ok(v) = s.parse::<f64>() {
        if v >= 0.0 && v.fract() == 0.0 {
            return Some(v as usize);
        }
    }
    let (num, mult) = match s.chars().last()? {
        'k' | 'K' => (&s[..s.len() - 1], 1_000usize),
        'm' | 'M' => (&s[..s.len() - 1], 1_000_000),
        'g' | 'G' => (&s[..s.len() - 1], 1_000_000_000),
        't' | 'T' => (&s[..s.len() - 1], 1_000_000_000_000),
        _ => return None,
    };
    let base: f64 = num.parse().ok()?;
    Some((base * mult as f64) as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str], opts: &[&'static str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), opts).unwrap()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = args(
            &["gen", "--streams", "64", "--rows=4096", "--verbose", "out.bin"],
            &["streams", "rows"],
        );
        assert_eq!(a.positional, vec!["gen", "out.bin"]);
        assert_eq!(a.get("streams"), Some("64"));
        assert_eq!(a.get("rows"), Some("4096"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn missing_value_is_error() {
        let r = Args::parse(["--streams".to_string()].into_iter(), &["streams"]);
        assert!(r.is_err());
    }

    #[test]
    fn scaled_counts() {
        assert_eq!(parse_scaled("4k"), Some(4_000));
        assert_eq!(parse_scaled("16M"), Some(16_000_000));
        assert_eq!(parse_scaled("2G"), Some(2_000_000_000));
        assert_eq!(parse_scaled("2^20"), Some(1 << 20));
        assert_eq!(parse_scaled("1e6"), Some(1_000_000));
        assert_eq!(parse_scaled("123"), Some(123));
        assert_eq!(parse_scaled("x"), None);
    }

    #[test]
    fn expect_audits_options_flags_and_positionals() {
        let a = args(&["report", "--streams", "64", "--quick"], &["streams"]);
        assert!(a.expect(&["streams"], &["quick"], 1).is_ok());
        assert!(a.expect(&["rows"], &["quick"], 1).is_err(), "option not taken");
        assert!(a.expect(&["streams"], &[], 1).is_err(), "flag not taken");
        assert!(a.expect(&["streams"], &["quick"], 0).is_err(), "extra positional");
    }

    #[test]
    fn unknown_option_detected() {
        let a = args(&["--bogus=1"], &["streams"]);
        assert!(a.expect(&["streams"], &[], 0).is_err());
        let a = args(&["--streams=1"], &["streams"]);
        assert!(a.expect(&["streams"], &[], 0).is_ok());
    }
}
