//! Minimal JSON parser — enough to read `artifacts/manifest.json` and to
//! serialize report output. (The build environment is offline; serde is not
//! available, so we carry our own small, well-tested parser.)

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// All JSON numbers are carried as f64 plus the raw text, so integer
    /// values up to u64::MAX can be recovered exactly via [`Json::as_u64`].
    Num(f64, String),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            bail!("trailing characters at offset {}", p.pos);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required fields.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v, _) => Some(*v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(_, raw) => raw.parse::<u64>().ok(),
            Json::Str(s) => s.parse::<u64>().ok(), // stringified u64s
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at offset {}", c as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| anyhow!("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at offset {}", c as char, self.pos),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            bail!("invalid literal at offset {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("expected ',' or '}}' at offset {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("expected ',' or ']' at offset {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self
                .b
                .get(self.pos)
                .ok_or_else(|| anyhow!("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self
                        .b
                        .get(self.pos)
                        .ok_or_else(|| anyhow!("unterminated escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            // Surrogate pairs are not needed for our
                            // manifests; reject them loudly.
                            let ch = char::from_u32(code)
                                .ok_or_else(|| anyhow!("surrogate \\u escape unsupported"))?;
                            s.push(ch);
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    let chunk = self
                        .b
                        .get(start..end)
                        .ok_or_else(|| anyhow!("truncated utf-8"))?;
                    s.push_str(std::str::from_utf8(chunk)?);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let raw = std::str::from_utf8(&self.b[start..self.pos])?;
        let v: f64 = raw.parse()?;
        Ok(Json::Num(v, raw.to_string()))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// A float as a JSON number. Rust formats non-finite floats as
/// `NaN`/`inf`, which is not valid JSON; those serialize as `null` so
/// every emitted document parses. The one constructor behind all of
/// the crate's writers (bench trajectories, lint baselines, STATS
/// exports) — shared escaping and non-finite handling by construction.
pub fn num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v, format!("{v}"))
    } else {
        Json::Null
    }
}

/// A `u64` as a JSON number, exact at full precision: the raw decimal
/// text rides along so values beyond 2^53 survive a parse round-trip
/// via [`Json::as_u64`] (counters are u64; f64 would silently round).
pub fn uint(v: u64) -> Json {
    Json::Num(v as f64, format!("{v}"))
}

/// Escape a string for JSON output.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl Json {
    /// Pretty form: two-space indentation, one member per line, a space
    /// after each colon — the layout of the committed trajectory files
    /// (`LINT.json`). Compact form is the `Display` impl.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.pretty_into(&mut out, 0);
        out.push('\n');
        out
    }

    fn pretty_into(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent + 1);
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    out.push_str(if i > 0 { ",\n" } else { "\n" });
                    out.push_str(&pad);
                    e.pretty_into(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    out.push_str(if i > 0 { ",\n" } else { "\n" });
                    out.push_str(&pad);
                    out.push('"');
                    out.push_str(&escape(k));
                    out.push_str("\": ");
                    v.pretty_into(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
            leaf => out.push_str(&leaf.to_string()),
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(_, raw) => write!(f, "{raw}"),
            Json::Str(s) => write!(f, "\"{}\"", escape(s)),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{e}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "\"{}\":{v}", escape(k))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "lcg": {"a": "6364136223846793005", "c": "55", "m_bits": 64},
            "seeds": [1812433253, 2567483615],
            "flag": true, "nothing": null, "ratio": -1.5e2
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("lcg").unwrap().get("a").unwrap().as_u64(), Some(6364136223846793005));
        assert_eq!(v.get("lcg").unwrap().get("m_bits").unwrap().as_usize(), Some(64));
        assert_eq!(v.get("seeds").unwrap().as_arr().unwrap()[1].as_u64(), Some(2567483615));
        assert_eq!(v.get("flag"), Some(&Json::Bool(true)));
        assert_eq!(v.get("nothing"), Some(&Json::Null));
        assert_eq!(v.get("ratio").unwrap().as_f64(), Some(-150.0));
    }

    #[test]
    fn u64_precision_preserved() {
        // 2^64 - 1 is not representable in f64; the raw text path must
        // recover it exactly.
        let v = Json::parse("{\"x\": 18446744073709551615}").unwrap();
        assert_eq!(v.get("x").unwrap().as_u64(), Some(u64::MAX));
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\n\t\"\\Ab""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Ab"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"π ≈ 3.14159\"").unwrap();
        assert_eq!(v.as_str(), Some("π ≈ 3.14159"));
    }

    #[test]
    fn display_roundtrip() {
        let doc = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":false}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn pretty_roundtrips_and_keeps_the_trajectory_layout() {
        let doc = r#"{"deny":{"panic":0},"schema":1,"tags":[1,2]}"#;
        let v = Json::parse(doc).unwrap();
        let p = v.pretty();
        assert_eq!(Json::parse(&p).unwrap(), v, "pretty text parses back equal");
        assert!(p.contains("  \"deny\": {\n    \"panic\": 0\n  }"), "{p}");
        assert!(p.ends_with("}\n"));
        assert_eq!(Json::parse("{}").unwrap().pretty(), "{}\n");
    }

    #[test]
    fn num_constructor_nulls_non_finite() {
        assert_eq!(num(2.5).to_string(), "2.5");
        assert_eq!(num(f64::NAN), Json::Null);
        assert_eq!(num(f64::INFINITY), Json::Null);
        assert_eq!(num(f64::NEG_INFINITY), Json::Null);
    }

    #[test]
    fn uint_constructor_is_exact_at_full_precision() {
        let v = uint(u64::MAX);
        assert_eq!(v.to_string(), "18446744073709551615");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(back.as_u64(), Some(u64::MAX));
    }
}
