//! Canonical unit-interval conversions: u32/u64 draws → `[0, 1)` floats.
//!
//! Every consumer that turns raw stream words into floats — the
//! Monte-Carlo apps, the `Prng32` float views, the distribution-shaping
//! samplers (`crate::dist`) — goes through these functions, so the
//! exact output bits are pinned in ONE place (known-answer tests below)
//! instead of being re-derived per call site. The conversions differ in
//! how many input bits survive:
//!
//! | fn              | input        | density | form                          |
//! |-----------------|--------------|---------|-------------------------------|
//! | [`f32_24`]      | 1 × u32      | 24-bit  | `(x >> 8) · 2⁻²⁴` (f32 mantissa capacity) |
//! | [`f64_24`]      | 1 × u32      | 24-bit  | same bits widened to f64      |
//! | [`f64_32`]      | 1 × u32      | 32-bit  | `x · 2⁻³²` (exact in f64)     |
//! | [`f64_53`]      | 2 × u32      | 53-bit  | 26 + 27 bits → `· 2⁻⁵³`       |
//! | [`f64_from_u64`]| 1 × u64      | 53-bit  | `(x >> 11) · 2⁻⁵³`            |
//!
//! All outputs lie in `[0, 1)` — 1.0 is never produced.

/// f32 in `[0, 1)` from the top 24 bits of one draw (the f32 mantissa
/// capacity) — the π app's conversion.
#[inline]
pub fn f32_24(x: u32) -> f32 {
    (x >> 8) as f32 * (1.0 / 16_777_216.0)
}

/// f64 in `[0, 1)` from the top 24 bits of one draw — the
/// option-pricing kernel's conversion (24-bit density kept so the
/// pre-`util::unit` bits are preserved exactly).
#[inline]
pub fn f64_24(x: u32) -> f64 {
    (x >> 8) as f64 * (1.0 / 16_777_216.0)
}

/// f64 in `[0, 1)` from all 32 bits of one draw (exact: an f64 mantissa
/// holds 53 bits) — the single-draw shaping conversion.
#[inline]
pub fn f64_32(x: u32) -> f64 {
    f64::from(x) * (1.0 / 4_294_967_296.0)
}

/// f64 in `[0, 1)` with full 53-bit density from two draws (26 bits of
/// `hi`, 27 bits of `lo`) — `Prng32::next_f64`'s pairing, also used by
/// the shaping samplers that need fine tail resolution (exponential,
/// Poisson inverse-CDF).
#[inline]
pub fn f64_53(hi: u32, lo: u32) -> f64 {
    let hi = u64::from(hi >> 6); // 26 bits
    let lo = u64::from(lo >> 5); // 27 bits
    ((hi << 27) | lo) as f64 * (1.0 / 9_007_199_254_740_992.0)
}

/// f64 in `[0, 1)` from the top 53 bits of one u64 draw.
#[inline]
pub fn f64_from_u64(x: u64) -> f64 {
    (x >> 11) as f64 * (1.0 / 9_007_199_254_740_992.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Known-answer tests: the exact output BITS are part of the
    // contract (the apps' published results and the shaped-stream
    // replay contract both depend on them), so the expectations are
    // hex bit patterns, not approximate comparisons.
    #[test]
    fn f32_24_known_answers() {
        assert_eq!(f32_24(0).to_bits(), 0);
        // 2^-24: exponent 127-24 = 103.
        assert_eq!(f32_24(0x0000_0100).to_bits(), 103u32 << 23);
        // 1 - 2^-24: all-ones mantissa just below 1.0.
        assert_eq!(f32_24(u32::MAX).to_bits(), 0x3F7F_FFFF);
        // Low 8 bits are discarded.
        assert_eq!(f32_24(0x1234_56FF), f32_24(0x1234_5600));
    }

    #[test]
    fn f64_24_known_answers() {
        assert_eq!(f64_24(0).to_bits(), 0);
        // 2^-24: exponent 1023-24 = 999.
        assert_eq!(f64_24(0x0000_0100).to_bits(), 999u64 << 52);
        // 1 - 2^-24.
        assert_eq!(f64_24(u32::MAX).to_bits(), 0x3FEF_FFFF_E000_0000);
        assert_eq!(f64_24(0xABCD_EFFF), f64_24(0xABCD_EF00));
    }

    #[test]
    fn f64_32_known_answers() {
        assert_eq!(f64_32(0).to_bits(), 0);
        // 2^-32: exponent 1023-32 = 991.
        assert_eq!(f64_32(1).to_bits(), 991u64 << 52);
        assert_eq!(f64_32(1 << 31), 0.5);
        // 1 - 2^-32.
        assert_eq!(f64_32(u32::MAX).to_bits(), 0x3FEF_FFFF_FFE0_0000);
    }

    #[test]
    fn f64_53_known_answers() {
        assert_eq!(f64_53(0, 0).to_bits(), 0);
        // Lowest surviving bit of `lo`: 2^-53 (exponent 1023-53 = 970).
        assert_eq!(f64_53(0, 1 << 5).to_bits(), 970u64 << 52);
        // Lowest surviving bit of `hi`: 2^-26 (exponent 1023-26 = 997).
        assert_eq!(f64_53(1 << 6, 0).to_bits(), 997u64 << 52);
        // 1 - 2^-53: the largest producible value.
        assert_eq!(f64_53(u32::MAX, u32::MAX).to_bits(), 0x3FEF_FFFF_FFFF_FFFF);
        // Discarded bits: low 6 of hi, low 5 of lo.
        assert_eq!(f64_53(0xFFFF_FFC0, 0xFFFF_FFE0), f64_53(u32::MAX, u32::MAX));
    }

    #[test]
    fn f64_from_u64_known_answers() {
        assert_eq!(f64_from_u64(0).to_bits(), 0);
        assert_eq!(f64_from_u64(1 << 11).to_bits(), 970u64 << 52);
        assert_eq!(f64_from_u64(u64::MAX).to_bits(), 0x3FEF_FFFF_FFFF_FFFF);
        assert_eq!(f64_from_u64(1 << 63), 0.5);
    }

    #[test]
    fn everything_stays_in_the_unit_interval() {
        for x in [0u32, 1, 0x8000_0000, 0xDEAD_BEEF, u32::MAX] {
            assert!((0.0..1.0).contains(&f64::from(f32_24(x))), "f32_24({x:#x})");
            assert!((0.0..1.0).contains(&f64_24(x)), "f64_24({x:#x})");
            assert!((0.0..1.0).contains(&f64_32(x)), "f64_32({x:#x})");
            for y in [0u32, u32::MAX] {
                assert!((0.0..1.0).contains(&f64_53(x, y)), "f64_53({x:#x},{y:#x})");
            }
        }
        assert!((0.0..1.0).contains(&f64_from_u64(u64::MAX)));
    }
}
