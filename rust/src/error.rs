//! The crate-level error type shared by every engine and client surface.
//!
//! Before the [`StreamSource`](crate::coordinator::StreamSource) redesign,
//! drain failures surfaced as a coordinator-local `FetchError` on one
//! engine and as stringly `anyhow` errors on the other; callers matching
//! on backpressure had to parse messages. This enum is the single failure
//! vocabulary of the public API: every engine, the builder, and
//! [`StreamHandle`](crate::coordinator::StreamHandle) return it, and the
//! blanket `std::error::Error` conversion keeps `?` working in
//! `anyhow`-returning application code.

/// `Result` specialized to the crate-level [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Every failure mode of the generation service.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Error {
    /// The requested advance would stretch a group's fastest−slowest lane
    /// spread beyond its lag window — the service's backpressure signal.
    /// Catch the slow lanes up (or widen the window at build time) and
    /// retry; the rejected call consumed nothing.
    LagWindowExceeded {
        /// The spread (in rows) the rejected call would have created.
        lead: u64,
        /// The configured bound on the spread.
        window: u64,
    },
    /// The stream id is not served by this source.
    UnknownStream {
        /// The requested stream id.
        stream: u64,
        /// How many streams the source serves (ids `0..have`).
        have: u64,
    },
    /// The group index is not served by this source.
    GroupOutOfRange {
        /// The requested group index.
        group: usize,
        /// How many groups the source serves (indices `0..have`).
        have: usize,
    },
    /// [`EngineBuilder`](crate::coordinator::EngineBuilder) rejected the
    /// requested configuration before constructing anything.
    InvalidConfig(String),
    /// Generation-backend failure (artifact error, device thread gone,
    /// worker shard died).
    Backend(String),
    /// A generator name was not found in a comparison roster (e.g. the
    /// Table 5 scaling rows) — returned instead of panicking when a row
    /// is dropped or renamed.
    UnknownGenerator {
        /// The requested generator name (prefix-matched).
        name: String,
    },
    /// The serving layer's wire protocol broke down: an I/O failure, a
    /// malformed or truncated frame, a version mismatch, or a peer that
    /// closed mid-conversation (`rust/src/serve/`). The connection is
    /// unusable afterwards — reconnect rather than retry the call.
    Protocol(String),
    /// The request was cancelled (via a
    /// [`CancelHandle`](crate::coordinator::CancelHandle) or a wire
    /// CANCEL) before it started executing. A cancelled request consumed
    /// no stream state — the stream replays as if it was never
    /// submitted. Not retryable: the caller (or its peer) asked for the
    /// work not to happen.
    Cancelled,
    /// The request's (or the wait's) deadline passed before service
    /// began. Like a cancellation, an expired request consumed no stream
    /// state, so resubmitting with a fresh deadline is always safe —
    /// which is why this variant *is* retryable.
    DeadlineExceeded,
    /// The serving layer's per-tenant admission control rejected the
    /// request: admitting it would push the tenant's in-flight
    /// sub-request count past its quota. The rejected request consumed
    /// no stream state, so retrying once earlier work drains is always
    /// safe — this is the multi-tenant analogue of
    /// [`Error::LagWindowExceeded`].
    QuotaExceeded {
        /// The tenant's in-flight sub-request count at rejection time.
        in_flight: u64,
        /// The configured per-tenant bound.
        quota: u64,
    },
}

impl Error {
    /// Is this a transient condition the caller can recover from by
    /// retrying (after letting the rest of the system make progress)?
    ///
    /// [`Error::LagWindowExceeded`] qualifies: it is the service's
    /// backpressure signal, cleared as soon as the group's slow lanes
    /// catch up. [`Error::DeadlineExceeded`] qualifies too: an expired
    /// request (or wait) consumed nothing, so resubmitting with a fresh
    /// deadline continues the stream seamlessly. So does
    /// [`Error::QuotaExceeded`]: admission control rejected the request
    /// whole, and the tenant's earlier work draining clears it. Every
    /// other variant is persistent — retrying an unknown stream or a
    /// dead backend returns the same error, and retrying a
    /// [`Error::Cancelled`] request would undo a deliberate caller
    /// decision.
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            Error::LagWindowExceeded { .. }
                | Error::DeadlineExceeded
                | Error::QuotaExceeded { .. }
        )
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::LagWindowExceeded { lead, window } => {
                write!(f, "stream lead {lead} exceeds lag window {window}")
            }
            Error::UnknownStream { stream, have } => {
                write!(f, "stream {stream} not registered (have {have})")
            }
            Error::GroupOutOfRange { group, have } => {
                write!(f, "group {group} out of range (have {have})")
            }
            Error::InvalidConfig(msg) => write!(f, "invalid engine config: {msg}"),
            Error::Backend(msg) => write!(f, "backend: {msg}"),
            Error::UnknownGenerator { name } => {
                write!(f, "generator {name:?} not in the roster")
            }
            Error::Protocol(msg) => write!(f, "protocol: {msg}"),
            Error::Cancelled => write!(f, "request cancelled before execution"),
            Error::DeadlineExceeded => write!(f, "deadline exceeded before service"),
            Error::QuotaExceeded { in_flight, quota } => {
                write!(f, "tenant quota exceeded ({in_flight} in flight, quota {quota})")
            }
        }
    }
}

impl std::error::Error for Error {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_keeps_backpressure_greppable() {
        // Client code (and the stress tests) match on this phrase.
        let e = Error::LagWindowExceeded { lead: 20, window: 10 };
        assert!(format!("{e}").contains("lag window"));
    }

    #[test]
    fn only_backpressure_and_expiry_are_retryable() {
        assert!(Error::LagWindowExceeded { lead: 2, window: 1 }.is_retryable());
        // An expired request consumed nothing — resubmission is safe.
        assert!(Error::DeadlineExceeded.is_retryable());
        // A quota rejection consumed nothing either — retry after drain.
        assert!(Error::QuotaExceeded { in_flight: 9, quota: 8 }.is_retryable());
        // A cancellation is a deliberate caller decision, not transient.
        assert!(!Error::Cancelled.is_retryable());
        assert!(!Error::UnknownStream { stream: 9, have: 8 }.is_retryable());
        assert!(!Error::Backend("gone".into()).is_retryable());
        assert!(!Error::UnknownGenerator { name: "WELL".into() }.is_retryable());
        assert!(!Error::Protocol("short frame".into()).is_retryable());
    }

    #[test]
    fn converts_into_anyhow() {
        fn fallible() -> anyhow::Result<()> {
            Err(Error::InvalidConfig("zero streams".into()))?;
            Ok(())
        }
        let err = fallible().unwrap_err();
        assert!(format!("{err}").contains("zero streams"));
    }
}
