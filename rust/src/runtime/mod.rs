//! PJRT runtime — loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Interchange format is HLO *text* (not serialized protos): jax >= 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! `PjRtClient` is `Rc`-backed (not `Send`), so all device interaction is
//! confined to whichever thread builds the [`Runtime`]; cross-thread access
//! goes through [`executor::TileExecutor`], which owns a dedicated device
//! thread — the software analogue of the paper's single RSGU feeding many
//! SOUs.

pub mod executor;
pub mod manifest;

// The PJRT bindings are an out-of-tree crate; default builds substitute a
// compile-time stub so the whole runtime layer typechecks offline. The
// stub's client constructor always errors, which the coordinator surfaces
// as "PJRT engine unavailable" (DESIGN.md §4).
#[cfg(not(feature = "xla"))]
mod xla_stub;
#[cfg(not(feature = "xla"))]
use self::xla_stub as xla;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, ensure, Context, Result};

pub use manifest::{ArtifactInfo, Manifest};

/// Carried generator state for one tile executable: the Layer-3 side of the
/// daisy chain — root state + per-stream decorrelator states, threaded
/// through successive tile invocations.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TileState {
    pub root: u64,
    pub h: Vec<u64>,
    pub xs: Vec<[u32; 4]>,
}

impl TileState {
    /// Canonical state for streams `first_stream .. first_stream+p`.
    pub fn new(root_seed: u64, p: usize, first_stream: u64) -> Self {
        let batch = crate::prng::ThunderingBatch::new(root_seed, p, first_stream);
        Self {
            root: batch.root_state(),
            h: (0..p as u64)
                .map(|i| crate::prng::thundering::leaf_h(first_stream + i))
                .collect(),
            xs: batch.xs_states(),
        }
    }

    pub fn width(&self) -> usize {
        self.h.len()
    }

    fn xs_flat(&self) -> Vec<u32> {
        // (4, p) row-major: lane k of every stream, then lane k+1 ...
        let p = self.xs.len();
        let mut flat = vec![0u32; 4 * p];
        for (i, s) in self.xs.iter().enumerate() {
            for k in 0..4 {
                flat[k * p + i] = s[k];
            }
        }
        flat
    }

    fn set_xs_flat(&mut self, flat: &[u32]) {
        let p = self.xs.len();
        debug_assert_eq!(flat.len(), 4 * p);
        for i in 0..p {
            for k in 0..4 {
                self.xs[i][k] = flat[k * p + i];
            }
        }
    }
}

/// One loaded tile executable plus its shape metadata.
pub struct TileExe {
    pub name: String,
    pub info: ArtifactInfo,
    exe: xla::PjRtLoadedExecutable,
}

impl TileExe {
    /// Execute a thundering tile: fills `out` (rows*p, row-major) and
    /// advances `state` in place.
    pub fn run_thundering(&self, state: &mut TileState, out: &mut [u32]) -> Result<()> {
        let p = self.info.p;
        let rows = self.info.rows;
        ensure!(state.width() == p, "state width {} != artifact p {p}", state.width());
        ensure!(out.len() == rows * p, "out len {} != {}", out.len(), rows * p);

        let results = self.exe.execute::<xla::Literal>(&self.thundering_inputs(state)?)?;
        let tuple = results[0][0].to_literal_sync()?.to_tuple()?;
        let [out_lit, root_lit, xs_lit]: [xla::Literal; 3] = tuple
            .try_into()
            .map_err(|_| anyhow!("artifact {}: expected 3-tuple output", self.name))?;

        // copy_raw_to writes straight into the caller's buffer — one copy
        // instead of to_vec's allocate+copy (§Perf L3).
        out_lit.copy_raw_to(out)?;
        state.root = root_lit.to_vec::<u64>()?[0];
        state.set_xs_flat(&xs_lit.to_vec::<u32>()?);
        Ok(())
    }

    fn thundering_inputs(&self, state: &TileState) -> Result<[xla::Literal; 3]> {
        let p = self.info.p as i64;
        Ok([
            xla::Literal::vec1(&[state.root]),
            xla::Literal::vec1(&state.h),
            xla::Literal::vec1(&state.xs_flat()).reshape(&[4, p])?,
        ])
    }

    /// Execute the pi tile: returns the in-circle hit count for
    /// rows/2 * p draws; advances `state`.
    pub fn run_pi(&self, state: &mut TileState) -> Result<u32> {
        let results = self.exe.execute::<xla::Literal>(&self.thundering_inputs(state)?)?;
        let tuple = results[0][0].to_literal_sync()?.to_tuple()?;
        let [hits_lit, root_lit, xs_lit]: [xla::Literal; 3] =
            tuple.try_into().map_err(|_| anyhow!("pi tile: expected 3-tuple"))?;
        state.root = root_lit.to_vec::<u64>()?[0];
        state.set_xs_flat(&xs_lit.to_vec::<u32>()?);
        Ok(hits_lit.get_first_element::<u32>()?)
    }

    /// Execute the Black–Scholes tile: returns the discounted-payoff sum
    /// over rows/2 * p draws; advances `state`.
    pub fn run_bs(&self, state: &mut TileState, params: &BsParams) -> Result<f32> {
        let p = self.info.p as i64;
        let inputs = [
            xla::Literal::vec1(&[state.root]),
            xla::Literal::vec1(&state.h),
            xla::Literal::vec1(&state.xs_flat()).reshape(&[4, p])?,
            xla::Literal::vec1(&[params.s0, params.k, params.r, params.sigma, params.t]),
        ];
        let results = self.exe.execute::<xla::Literal>(&inputs)?;
        let tuple = results[0][0].to_literal_sync()?.to_tuple()?;
        let [sum_lit, root_lit, xs_lit]: [xla::Literal; 3] =
            tuple.try_into().map_err(|_| anyhow!("bs tile: expected 3-tuple"))?;
        state.root = root_lit.to_vec::<u64>()?[0];
        state.set_xs_flat(&xs_lit.to_vec::<u32>()?);
        Ok(sum_lit.get_first_element::<f32>()?)
    }

    /// Execute the philox baseline tile (stateless counter mode).
    pub fn run_philox(&self, ctr_base: u64, key: [u32; 2], out: &mut [u32]) -> Result<()> {
        ensure!(out.len() == self.info.rows * self.info.p);
        let inputs = [xla::Literal::vec1(&[ctr_base]), xla::Literal::vec1(&key)];
        let results = self.exe.execute::<xla::Literal>(&inputs)?;
        let out_lit = results[0][0].to_literal_sync()?.to_tuple1()?;
        out.copy_from_slice(&out_lit.to_vec::<u32>()?);
        Ok(())
    }

    /// Execute the lcg-only ablation tile.
    pub fn run_lcg_only(&self, root: &mut u64, h: &[u64], out: &mut [u32]) -> Result<()> {
        ensure!(out.len() == self.info.rows * self.info.p);
        let inputs = [xla::Literal::vec1(&[*root]), xla::Literal::vec1(h)];
        let results = self.exe.execute::<xla::Literal>(&inputs)?;
        let tuple = results[0][0].to_literal_sync()?.to_tuple()?;
        let [out_lit, root_lit]: [xla::Literal; 2] =
            tuple.try_into().map_err(|_| anyhow!("lcg tile: expected 2-tuple"))?;
        out.copy_from_slice(&out_lit.to_vec::<u32>()?);
        *root = root_lit.to_vec::<u64>()?[0];
        Ok(())
    }
}

/// Black–Scholes parameters for the option-pricing tile.
#[derive(Clone, Copy, Debug)]
pub struct BsParams {
    pub s0: f32,
    pub k: f32,
    pub r: f32,
    pub sigma: f32,
    pub t: f32,
}

impl Default for BsParams {
    fn default() -> Self {
        // The classic textbook configuration used by the cuRAND samples.
        Self { s0: 100.0, k: 100.0, r: 0.05, sigma: 0.2, t: 1.0 }
    }
}

/// Artifact loader + executable cache bound to one PJRT CPU client.
/// Single-threaded by construction (see module docs).
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<TileExe>>>,
}

impl Runtime {
    /// Open `artifacts_dir` (must contain manifest.json; run
    /// `make artifacts` first).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, manifest, dir, cache: RefCell::new(HashMap::new()) })
    }

    /// Default artifacts directory: $THUNDERING_ARTIFACTS or ./artifacts.
    pub fn open_default() -> Result<Self> {
        let dir = std::env::var("THUNDERING_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::new(dir)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (and cache) an artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Rc<TileExe>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let info = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?} (see manifest.json)"))?
            .clone();
        let path = self.dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile of {name}"))?;
        let tile = Rc::new(TileExe { name: name.to_string(), info, exe });
        self.cache.borrow_mut().insert(name.to_string(), tile.clone());
        Ok(tile)
    }

    /// All artifact names of a given kind.
    pub fn names_of_kind(&self, kind: &str) -> Vec<String> {
        self.manifest
            .artifacts
            .iter()
            .filter(|(_, a)| a.kind == kind)
            .map(|(n, _)| n.clone())
            .collect()
    }
}
