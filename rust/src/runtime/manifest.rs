//! artifacts/manifest.json — the contract between the AOT compile path
//! (python/compile/aot.py) and this runtime.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub lcg_a: u64,
    pub lcg_c: u64,
    pub lcg_m_bits: u32,
    pub xs_seed: [u32; 4],
    pub xs_stride_log2: u32,
    pub leaf_golden: u64,
    pub output_desc: String,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub kind: String,
    pub block: usize,
    pub p: usize,
    pub tiles: usize,
    /// Total output rows per invocation (= block * tiles).
    pub rows: usize,
    pub file: String,
    pub sha256: String,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::from_json_text(&text)
    }

    pub fn from_json_text(text: &str) -> Result<Self> {
        let v = Json::parse(text).context("parsing manifest.json")?;
        let lcg = v.req("lcg")?;
        let xs = v.req("xorshift128")?;
        let seed_arr = xs.req("seed")?.as_arr().ok_or_else(|| anyhow!("bad seed"))?;
        if seed_arr.len() != 4 {
            bail!("xorshift seed must have 4 words");
        }
        let mut xs_seed = [0u32; 4];
        for (i, s) in seed_arr.iter().enumerate() {
            xs_seed[i] = s.as_u64().ok_or_else(|| anyhow!("bad seed word"))? as u32;
        }
        let mut artifacts = BTreeMap::new();
        for (name, a) in v.req("artifacts")?.as_obj().ok_or_else(|| anyhow!("bad artifacts"))? {
            let info = ArtifactInfo {
                kind: a.req("kind")?.as_str().ok_or_else(|| anyhow!("bad kind"))?.into(),
                block: a.req("block")?.as_usize().ok_or_else(|| anyhow!("bad block"))?,
                p: a.req("p")?.as_usize().ok_or_else(|| anyhow!("bad p"))?,
                tiles: a.req("tiles")?.as_usize().ok_or_else(|| anyhow!("bad tiles"))?,
                rows: a.req("rows")?.as_usize().ok_or_else(|| anyhow!("bad rows"))?,
                file: a.req("file")?.as_str().ok_or_else(|| anyhow!("bad file"))?.into(),
                sha256: a
                    .get("sha256")
                    .and_then(|s| s.as_str())
                    .unwrap_or_default()
                    .into(),
            };
            artifacts.insert(name.clone(), info);
        }
        let m = Manifest {
            lcg_a: lcg.req("a")?.as_u64().ok_or_else(|| anyhow!("bad lcg.a"))?,
            lcg_c: lcg.req("c")?.as_u64().ok_or_else(|| anyhow!("bad lcg.c"))?,
            lcg_m_bits: lcg.req("m_bits")?.as_u64().ok_or_else(|| anyhow!("bad m_bits"))? as u32,
            xs_seed,
            xs_stride_log2: xs.req("substream_stride_log2")?.as_u64().unwrap_or(64) as u32,
            leaf_golden: v.req("leaf")?.req("golden")?.as_u64().unwrap_or(0),
            output_desc: v
                .get("output")
                .and_then(|s| s.as_str())
                .unwrap_or_default()
                .into(),
            artifacts,
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        if self.lcg_a != crate::prng::LCG_A || self.lcg_c != crate::prng::LCG_C {
            bail!(
                "manifest LCG params ({}, {}) do not match this binary ({}, {}) \
                 — artifacts and binary are out of sync; re-run `make artifacts`",
                self.lcg_a,
                self.lcg_c,
                crate::prng::LCG_A,
                crate::prng::LCG_C
            );
        }
        if self.xs_seed != crate::prng::xorshift::XS128_SEED {
            bail!("manifest xorshift seed mismatch");
        }
        if self.leaf_golden != crate::prng::thundering::LEAF_GOLDEN {
            bail!("manifest leaf schedule mismatch — re-run `make artifacts`");
        }
        for (name, info) in &self.artifacts {
            if info.rows != info.block * info.tiles {
                bail!("artifact {name}: rows != block*tiles");
            }
            if info.p == 0 || info.rows == 0 {
                bail!("artifact {name}: degenerate shape");
            }
        }
        Ok(())
    }

    /// Pick the best thundering artifact for a requested (rows, streams)
    /// workload: prefer p <= streams (widest), then closest rows.
    pub fn select_thundering(&self, rows: usize, streams: usize) -> Option<(&str, &ArtifactInfo)> {
        self.artifacts
            .iter()
            .filter(|(_, a)| a.kind == "thundering" || a.kind == "thundering_scan")
            .map(|(n, a)| (n.as_str(), a))
            .min_by_key(|(_, a)| {
                let width_gap =
                    if a.p <= streams { (streams - a.p) * 2 } else { (a.p - streams) * 1000 };
                let row_gap = a.rows.abs_diff(rows);
                width_gap * 1_000_000 + row_gap
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_text() -> &'static str {
        r#"{
            "lcg": {"a": "6364136223846793005", "c": "55", "m_bits": 64},
            "xorshift128": {"seed": [1812433253, 2567483615, 2636928640, 4022730752],
                            "substream_stride_log2": 64},
            "leaf": {"golden": "11400714819323198485", "note": ""},
            "output": "xsh_rr_64_32 XOR xorshift128",
            "artifacts": {
                "thundering_b256_p64": {"kind": "thundering", "block": 256, "p": 64,
                    "tiles": 1, "rows": 256, "file": "x.hlo.txt", "sha256": "", "bytes": 1},
                "thundering_b1024_p256": {"kind": "thundering", "block": 1024, "p": 256,
                    "tiles": 1, "rows": 1024, "file": "y.hlo.txt", "sha256": "", "bytes": 1}
            }
        }"#
    }

    #[test]
    fn parses_and_validates() {
        let m = Manifest::from_json_text(sample_text()).unwrap();
        assert_eq!(m.lcg_a, crate::prng::LCG_A);
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.artifacts["thundering_b256_p64"].rows, 256);
    }

    #[test]
    fn select_prefers_fitting_width() {
        let m = Manifest::from_json_text(sample_text()).unwrap();
        let (name, _) = m.select_thundering(1024, 300).unwrap();
        assert_eq!(name, "thundering_b1024_p256");
        let (name, _) = m.select_thundering(256, 64).unwrap();
        assert_eq!(name, "thundering_b256_p64");
    }

    #[test]
    fn rejects_bad_lcg() {
        let bad = sample_text().replace("\"55\"", "\"54\"");
        assert!(Manifest::from_json_text(&bad).is_err());
    }

    #[test]
    fn rejects_inconsistent_rows() {
        let bad = sample_text().replace("\"rows\": 256", "\"rows\": 999");
        assert!(Manifest::from_json_text(&bad).is_err());
    }
}
