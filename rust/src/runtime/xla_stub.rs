//! Compile-time stand-in for the `xla` (PJRT) bindings.
//!
//! The PJRT tile engine needs the out-of-tree `xla` crate, which is not
//! vendored in this repository. Default builds use this stub instead: it
//! mirrors exactly the API surface `runtime::mod` consumes, so the whole
//! crate (coordinator, apps, CLI, benches) compiles and runs on the native
//! and sharded engines, while every attempt to *construct* a PJRT client
//! reports a clear error. `--features xla` removes this stub, which only
//! compiles after the out-of-tree `xla` crate has been added to
//! `[dependencies]` — the feature is a seam, not a ready toggle (see
//! DESIGN.md §4).
//!
//! Because [`PjRtClient::cpu`] always fails, no executable or buffer can
//! ever be obtained, so the remaining method bodies are unreachable at
//! runtime — they exist purely to typecheck the callers.

#![allow(dead_code)]

use anyhow::{anyhow, Result};

fn unavailable<T>() -> Result<T> {
    Err(anyhow!(
        "built without the `xla` feature: the PJRT tile engine is unavailable \
         (use --engine native or --engine sharded; enabling the feature also \
         requires adding the out-of-tree `xla` crate to Cargo.toml, see \
         DESIGN.md §4)"
    ))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn copy_raw_to<T>(&self, _out: &mut [T]) -> Result<()> {
        unavailable()
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        unavailable()
    }
}
