//! Dedicated device-thread executor.
//!
//! `PjRtClient` is not `Send`, so one OS thread owns the [`Runtime`] and
//! everything else talks to it through a job channel. Jobs are `Send`
//! closures over `&Runtime`; results come back on per-job channels. The
//! coordinator's batcher sits in front of this, so the device thread sees
//! an ordered stream of tile executions — the same discipline as the
//! paper's daisy chain delivering one root state per cycle.

use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::Runtime;

type Job = Box<dyn FnOnce(&Runtime) + Send>;

/// Handle to the device thread. Cloning shares the same thread/queue.
#[derive(Clone)]
pub struct TileExecutor {
    tx: SyncSender<Job>,
}

/// Owns the join handle; the device thread exits when every
/// [`TileExecutor`] clone is dropped.
pub struct TileExecutorGuard {
    pub executor: TileExecutor,
    handle: Option<JoinHandle<()>>,
}

impl TileExecutor {
    /// Spawn a device thread over `artifacts_dir`. `queue_depth` bounds the
    /// number of queued jobs (backpressure: `submit` blocks when full,
    /// `try_submit` refuses).
    pub fn spawn(artifacts_dir: String, queue_depth: usize) -> Result<TileExecutorGuard> {
        let (tx, rx) = mpsc::sync_channel::<Job>(queue_depth);
        let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();
        let handle = std::thread::Builder::new()
            .name("thng-pjrt-dev".into())
            .spawn(move || {
                let rt = match Runtime::new(&artifacts_dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    job(&rt);
                }
            })?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => return Err(anyhow!("device thread failed to start: {msg}")),
            Err(_) => return Err(anyhow!("device thread died during startup")),
        }
        Ok(TileExecutorGuard { executor: TileExecutor { tx }, handle: Some(handle) })
    }

    /// Submit a job; returns a receiver for its result. Blocks if the
    /// device queue is full (the backpressure point).
    pub fn submit<R, F>(&self, f: F) -> Receiver<R>
    where
        R: Send + 'static,
        F: FnOnce(&Runtime) -> R + Send + 'static,
    {
        let (tx, rx) = mpsc::channel();
        let job: Job = Box::new(move |rt| {
            let _ = tx.send(f(rt));
        });
        // The only send error is a closed device thread; surfaced on recv.
        let _ = self.tx.send(job);
        rx
    }

    /// Non-blocking submit: returns Err(()) when the queue is full or the
    /// device thread is gone.
    pub fn try_submit<R, F>(&self, f: F) -> std::result::Result<Receiver<R>, ()>
    where
        R: Send + 'static,
        F: FnOnce(&Runtime) -> R + Send + 'static,
    {
        let (tx, rx) = mpsc::channel();
        let job: Job = Box::new(move |rt| {
            let _ = tx.send(f(rt));
        });
        match self.tx.try_send(job) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => Err(()),
        }
    }

    /// Submit and block for the result.
    pub fn call<R, F>(&self, f: F) -> Result<R>
    where
        R: Send + 'static,
        F: FnOnce(&Runtime) -> R + Send + 'static,
    {
        self.submit(f)
            .recv()
            .map_err(|_| anyhow!("device thread terminated before completing the job"))
    }
}

impl TileExecutorGuard {
    /// Drop all executor clones you hold, then call this to join the device
    /// thread.
    pub fn join(mut self) {
        let (tx, _rx) = mpsc::sync_channel::<Job>(1);
        self.executor.tx = tx; // release our hold on the real channel
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for TileExecutorGuard {
    fn drop(&mut self) {
        // Detach: the device thread exits on its own when the last
        // TileExecutor clone drops. Joining here could deadlock if clones
        // outlive the guard.
        let _ = self.handle.take();
    }
}
