//! Ranked lock facade: `std::sync` newtypes that carry their position
//! in the declared lock hierarchy ([`crate::check::lock_order`]).
//!
//! Every `Mutex`/`RwLock` in `serve/` and `coordinator/` is an
//! [`OrderedMutex`]/[`OrderedRwLock`]. In release builds the wrappers
//! compile down to the bare `std::sync` primitive — the rank is not
//! even stored. In debug builds (every test run) each acquisition is
//! checked against a thread-local stack of held ranks:
//!
//! * acquiring a rank **lower or equal** to one already held panics
//!   (equal is allowed for classes marked `multi`, which callers
//!   acquire as an index-ordered set — e.g. `fetch_many`'s per-group
//!   drain locks);
//! * re-acquiring the **same lock instance** on one thread panics
//!   (`std::sync::Mutex` would deadlock or abort; this names the lock
//!   and the order instead).
//!
//! So the hierarchy `thng-check` lints at rest is also asserted under
//! load, on every test, interleaving included.
//!
//! Poisoning policy mirrors the crate's two established idioms:
//! [`OrderedMutex::lock`] recovers the guard (every critical section
//! here leaves state consistent between updates), while
//! [`OrderedMutex::lock_checked`] maps poisoning to the typed
//! [`Error::Backend`] for drain-state locks whose mid-fetch panic may
//! leave a partially advanced cursor.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::{Condvar, Mutex, MutexGuard, RwLock};
use std::time::Duration;

use crate::check::lock_order::LockRank;
use crate::error::Error;

/// The typed poisoning error `lock_checked` surfaces (same contract the
/// old `coordinator::lock_serve` helper had).
fn poisoned(rank: &'static LockRank) -> Error {
    Error::Backend(format!(
        "lock `{}` poisoned: a thread panicked inside the critical section \
         and its state may be inconsistent",
        rank.name
    ))
}

#[cfg(debug_assertions)]
mod held {
    //! The per-thread held-rank stack behind the debug assertions.
    use super::LockRank;
    use std::cell::RefCell;

    thread_local! {
        /// `(rank, lock address)` for every ordered lock this thread
        /// holds, in acquisition order.
        static HELD: RefCell<Vec<(u16, usize)>> = const { RefCell::new(Vec::new()) };
    }

    pub(super) fn acquire(rank: &'static LockRank, addr: usize) {
        HELD.with(|h| {
            let mut h = h.borrow_mut();
            if h.iter().any(|&(_, a)| a == addr) {
                panic!(
                    "lock-order: reentrant acquisition of `{}` (rank {}) on one thread \
                     — std::sync::Mutex would deadlock here",
                    rank.name, rank.rank
                );
            }
            if let Some(&(top, _)) = h.last() {
                let ok = rank.rank > top || (rank.rank == top && rank.multi);
                assert!(
                    ok,
                    "lock-order: acquiring `{}` (rank {}) while holding rank {} — \
                     violates the hierarchy declared in check::lock_order",
                    rank.name, rank.rank, top
                );
            }
            h.push((rank.rank, addr));
        });
    }

    pub(super) fn release(addr: usize) {
        HELD.with(|h| {
            let mut h = h.borrow_mut();
            if let Some(i) = h.iter().rposition(|&(_, a)| a == addr) {
                h.remove(i);
            }
        });
    }
}

// ---------------------------------------------------------------------------
// OrderedMutex

/// A [`std::sync::Mutex`] that knows its rank in the declared lock
/// hierarchy (see the module docs).
pub struct OrderedMutex<T> {
    #[cfg(debug_assertions)]
    rank: &'static LockRank,
    inner: Mutex<T>,
}

impl<T> OrderedMutex<T> {
    /// Wrap `value` under the declared `rank`.
    pub fn new(rank: &'static LockRank, value: T) -> Self {
        #[cfg(not(debug_assertions))]
        let _ = rank;
        Self {
            #[cfg(debug_assertions)]
            rank,
            inner: Mutex::new(value),
        }
    }

    #[cfg(debug_assertions)]
    fn addr(&self) -> usize {
        self as *const Self as *const u8 as usize
    }

    #[cfg(debug_assertions)]
    fn note_acquire(&self) {
        held::acquire(self.rank, self.addr());
    }

    /// Lock, recovering the guard from poisoning (the crate-wide
    /// default: critical sections keep their invariants between every
    /// update, so a peer's panic does not invalidate the state).
    pub fn lock(&self) -> OrderedGuard<'_, T> {
        #[cfg(debug_assertions)]
        self.note_acquire();
        let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        OrderedGuard {
            guard: Some(g),
            #[cfg(debug_assertions)]
            addr: self.addr(),
        }
    }

    /// Lock, mapping poisoning to the typed [`Error::Backend`] — for
    /// locks (the drain cores) whose mid-update panic can leave a
    /// partially advanced cursor behind.
    pub fn lock_checked(&self) -> Result<OrderedGuard<'_, T>, Error> {
        #[cfg(debug_assertions)]
        self.note_acquire();
        match self.inner.lock() {
            Ok(g) => Ok(OrderedGuard {
                guard: Some(g),
                #[cfg(debug_assertions)]
                addr: self.addr(),
            }),
            Err(_) => {
                #[cfg(debug_assertions)]
                held::release(self.addr());
                Err(self.poison_error())
            }
        }
    }

    /// Non-blocking [`lock_checked`](Self::lock_checked): `Ok(None)`
    /// when the lock is currently held elsewhere, `Err` on poisoning.
    pub fn try_lock_checked(&self) -> Result<Option<OrderedGuard<'_, T>>, Error> {
        use std::sync::TryLockError;
        match self.inner.try_lock() {
            Ok(g) => {
                #[cfg(debug_assertions)]
                self.note_acquire();
                Ok(Some(OrderedGuard {
                    guard: Some(g),
                    #[cfg(debug_assertions)]
                    addr: self.addr(),
                }))
            }
            Err(TryLockError::WouldBlock) => Ok(None),
            Err(TryLockError::Poisoned(_)) => Err(self.poison_error()),
        }
    }

    #[cfg(debug_assertions)]
    fn poison_error(&self) -> Error {
        poisoned(self.rank)
    }

    #[cfg(not(debug_assertions))]
    fn poison_error(&self) -> Error {
        Error::Backend(
            "lock poisoned: a thread panicked inside the critical section \
             and its state may be inconsistent"
                .into(),
        )
    }
}

impl<T: fmt::Debug> fmt::Debug for OrderedMutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.inner.fmt(f)
    }
}

/// RAII guard of an [`OrderedMutex`]; releases the rank (debug builds)
/// and the lock on drop. Carries the [`Condvar`] surface so waiting
/// keeps the rank accounting intact — the rank stays on the held stack
/// while the thread is blocked, which is correct: the lock is re-held
/// the moment `wait` returns.
pub struct OrderedGuard<'a, T> {
    /// `Some` except transiently inside the wait methods.
    guard: Option<MutexGuard<'a, T>>,
    #[cfg(debug_assertions)]
    addr: usize,
}

impl<'a, T> OrderedGuard<'a, T> {
    fn inner(&self) -> &MutexGuard<'a, T> {
        // Infallible: `guard` is only `None` mid-wait, and the wait
        // methods consume `self`.
        self.guard.as_ref().expect("guard present outside wait")
    }

    fn inner_mut(&mut self) -> &mut MutexGuard<'a, T> {
        self.guard.as_mut().expect("guard present outside wait")
    }

    /// Block on `cv` until notified, recovering from poisoning.
    pub fn wait(mut self, cv: &Condvar) -> Self {
        let g = self.guard.take().expect("guard present outside wait");
        let g = cv.wait(g).unwrap_or_else(|e| e.into_inner());
        self.guard = Some(g);
        self
    }

    /// Block on `cv` for at most `dur`, recovering from poisoning.
    /// Returns the reacquired guard and whether the wait timed out.
    pub fn wait_timeout(mut self, cv: &Condvar, dur: Duration) -> (Self, bool) {
        let g = self.guard.take().expect("guard present outside wait");
        let (g, t) = match cv.wait_timeout(g, dur) {
            Ok((g, t)) => (g, t.timed_out()),
            Err(e) => {
                let (g, t) = e.into_inner();
                (g, t.timed_out())
            }
        };
        self.guard = Some(g);
        (self, t)
    }

    /// [`wait_timeout`](Self::wait_timeout) with the typed-poisoning
    /// contract of [`OrderedMutex::lock_checked`]: a poisoned wake
    /// releases the lock and surfaces [`Error::Backend`].
    pub fn wait_timeout_checked(
        mut self,
        cv: &Condvar,
        dur: Duration,
        rank: &'static LockRank,
    ) -> Result<(Self, bool), Error> {
        let g = self.guard.take().expect("guard present outside wait");
        match cv.wait_timeout(g, dur) {
            Ok((g, t)) => {
                self.guard = Some(g);
                Ok((self, t.timed_out()))
            }
            // `self` (guard already taken) drops below and pops the
            // rank; the poisoned inner guard drops here.
            Err(_) => Err(poisoned(rank)),
        }
    }
}

impl<T> Deref for OrderedGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner()
    }
}

impl<T> DerefMut for OrderedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner_mut()
    }
}

impl<T> Drop for OrderedGuard<'_, T> {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        held::release(self.addr);
    }
}

// ---------------------------------------------------------------------------
// OrderedRwLock

/// A [`std::sync::RwLock`] that knows its rank. Read and write
/// acquisitions are ranked identically — a reader-vs-writer inversion
/// deadlocks exactly like a mutex inversion.
pub struct OrderedRwLock<T> {
    #[cfg(debug_assertions)]
    rank: &'static LockRank,
    inner: RwLock<T>,
}

impl<T> OrderedRwLock<T> {
    pub fn new(rank: &'static LockRank, value: T) -> Self {
        #[cfg(not(debug_assertions))]
        let _ = rank;
        Self {
            #[cfg(debug_assertions)]
            rank,
            inner: RwLock::new(value),
        }
    }

    #[cfg(debug_assertions)]
    fn addr(&self) -> usize {
        self as *const Self as *const u8 as usize
    }

    /// Shared lock, recovering from poisoning.
    pub fn read(&self) -> OrderedReadGuard<'_, T> {
        #[cfg(debug_assertions)]
        held::acquire(self.rank, self.addr());
        OrderedReadGuard {
            guard: self.inner.read().unwrap_or_else(|e| e.into_inner()),
            #[cfg(debug_assertions)]
            addr: self.addr(),
        }
    }

    /// Exclusive lock, recovering from poisoning.
    pub fn write(&self) -> OrderedWriteGuard<'_, T> {
        #[cfg(debug_assertions)]
        held::acquire(self.rank, self.addr());
        OrderedWriteGuard {
            guard: self.inner.write().unwrap_or_else(|e| e.into_inner()),
            #[cfg(debug_assertions)]
            addr: self.addr(),
        }
    }
}

impl<T: fmt::Debug> fmt::Debug for OrderedRwLock<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.inner.fmt(f)
    }
}

/// Shared-access guard of an [`OrderedRwLock`].
pub struct OrderedReadGuard<'a, T> {
    guard: std::sync::RwLockReadGuard<'a, T>,
    #[cfg(debug_assertions)]
    addr: usize,
}

impl<T> Deref for OrderedReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.guard
    }
}

impl<T> Drop for OrderedReadGuard<'_, T> {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        held::release(self.addr);
    }
}

/// Exclusive-access guard of an [`OrderedRwLock`].
pub struct OrderedWriteGuard<'a, T> {
    guard: std::sync::RwLockWriteGuard<'a, T>,
    #[cfg(debug_assertions)]
    addr: usize,
}

impl<T> Deref for OrderedWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.guard
    }
}

impl<T> DerefMut for OrderedWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.guard
    }
}

impl<T> Drop for OrderedWriteGuard<'_, T> {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        held::release(self.addr);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::lock_order::{DRAIN, INBOX, PARK, ROUTES, SESSION};

    #[test]
    fn ascending_acquisition_is_clean() {
        let a = OrderedMutex::new(&ROUTES, 1u32);
        let b = OrderedMutex::new(&SESSION, 2u32);
        let ga = a.lock();
        let gb = b.lock();
        assert_eq!(*ga + *gb, 3);
        drop(ga); // out-of-order release is fine
        drop(gb);
        // Sequential re-acquisition after release is fine too.
        assert_eq!(*a.lock(), 1);
    }

    #[test]
    fn same_rank_multi_class_allows_an_ordered_set() {
        let drains: Vec<_> = (0..4).map(|i| OrderedMutex::new(&DRAIN, i)).collect();
        let guards: Vec<_> = drains.iter().map(|d| d.lock()).collect();
        assert_eq!(guards.iter().map(|g| **g).sum::<i32>(), 6);
    }

    #[cfg(debug_assertions)]
    #[test]
    fn descending_acquisition_panics_in_debug() {
        let hi = OrderedMutex::new(&PARK, ());
        let lo = OrderedMutex::new(&INBOX, ());
        let _g = hi.lock();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = lo.lock();
        }))
        .expect_err("descending order must be rejected");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("lock-order"), "got: {msg}");
    }

    #[cfg(debug_assertions)]
    #[test]
    fn same_thread_reentrancy_panics_in_debug() {
        let m = OrderedMutex::new(&SESSION, ());
        let _g = m.lock();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = m.lock();
        }))
        .expect_err("reentrancy must be rejected, not deadlock");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("reentrant"), "got: {msg}");
    }

    #[test]
    fn condvar_wait_keeps_rank_accounting() {
        use std::sync::Arc;
        use std::time::Duration;
        let m = Arc::new(OrderedMutex::new(&PARK, 0u64));
        let cv = Arc::new(Condvar::new());
        let (m2, cv2) = (m.clone(), cv.clone());
        let t = std::thread::Builder::new()
            .name("thng-test-cv".into())
            .spawn(move || {
                let mut g = m2.lock();
                *g += 1;
                cv2.notify_all();
            })
            .expect("spawn");
        let mut g = m.lock();
        while *g == 0 {
            let (g2, _timed_out) = g.wait_timeout(&cv, Duration::from_millis(50));
            g = g2;
        }
        assert_eq!(*g, 1);
        drop(g);
        t.join().expect("join");
        // After the waits the held stack is balanced: a fresh
        // descending-order pair would still be the only way to panic.
        let again = m.lock();
        assert_eq!(*again, 1);
    }

    #[test]
    fn try_lock_reports_contention_as_none() {
        let m = OrderedMutex::new(&DRAIN, 7u8);
        let g = m.lock();
        // Same thread: the reentrancy debug check would fire before the
        // inner try_lock, so probe from another thread.
        std::thread::scope(|s| {
            s.spawn(|| {
                let r = m.try_lock_checked().expect("not poisoned");
                assert!(r.is_none(), "held elsewhere means WouldBlock");
            });
        });
        drop(g);
        let r = m.try_lock_checked().expect("not poisoned");
        assert_eq!(*r.expect("free now"), 7);
    }
}
