//! Generator benches: scalar throughput of every algorithm (Table 1's
//! cost column, measured), the state-sharing batch engine across widths
//! (Fig. 7's CPU core), and jump-ahead costs.
//!
//! Run: `cargo bench --bench bench_generators` (BENCH_ITERS=n to adjust).

use thundering::prng::mrg32k3a::Mrg32k3aFamily;
use thundering::prng::philox::PhiloxFamily;
use thundering::prng::tausworthe::LutSrFamily;
use thundering::prng::thundering::ThunderingFamily;
use thundering::prng::xoroshiro::XoroshiroFamily;
use thundering::prng::{
    Lcg64, Mt19937, PcgXshRs64, Prng32, SplitMix64, StreamFamily, ThunderingBatch,
    ThunderingStream,
};
use thundering::util::bench::{black_box, Bench};

const N: usize = 1 << 22; // words per measurement

fn bench_scalar(b: &Bench, name: &str, gen: &mut dyn Prng32) {
    let mut acc = 0u32;
    b.run(&format!("scalar/{name}"), N as u64, || {
        for _ in 0..N {
            acc ^= gen.next_u32();
        }
        black_box(acc);
    });
}

fn main() {
    let b = Bench::from_env();
    println!("# scalar generator throughput ({N} words/iter)");
    bench_scalar(&b, "thundering", &mut ThunderingStream::new(42, 0));
    bench_scalar(&b, "splitmix64", &mut SplitMix64::new(42));
    bench_scalar(&b, "lcg64", &mut Lcg64::new(42));
    bench_scalar(&b, "pcg_xsh_rs_64", &mut PcgXshRs64::new(42, 0));
    bench_scalar(&b, "xoroshiro128**", &mut XoroshiroFamily { seed: 7 }.stream(0));
    bench_scalar(&b, "philox4x32", &mut PhiloxFamily { base_key: [7, 99] }.stream(0));
    bench_scalar(&b, "mrg32k3a", &mut Mrg32k3aFamily { seed: 7 }.stream(0));
    bench_scalar(&b, "mt19937", &mut Mt19937::new(5489));
    bench_scalar(&b, "lut-sr", &mut LutSrFamily { seed: 7 }.stream(0));

    println!("\n# state-sharing batch engine (rows x width = {N} numbers/iter)");
    for width in [16usize, 64, 256, 1024] {
        let rows = N / width;
        let mut batch = ThunderingBatch::new(42, width, 0);
        let mut buf = vec![0u32; N];
        b.run(&format!("batch/width{width}"), N as u64, || {
            batch.fill_rows(rows, &mut buf);
            black_box(&buf);
        });
    }

    println!("\n# multistream scalar engines at width 64 (comparison point)");
    {
        let fam = ThunderingFamily::new(42);
        let mut streams: Vec<ThunderingStream> = (0..64).map(|i| fam.stream(i)).collect();
        let rows = N / 64;
        let mut buf = vec![0u32; N];
        b.run("multistream/thundering-64-scalar", N as u64, || {
            for r in 0..rows {
                for (i, s) in streams.iter_mut().enumerate() {
                    buf[r * 64 + i] = s.next_u32();
                }
            }
            black_box(&buf);
        });
    }

    println!("\n# jump-ahead (per jump)");
    b.run("jump/lcg_2^40", 1, || {
        black_box(thundering::prng::lcg::lcg_jump(
            black_box(12345),
            1 << 40,
            thundering::prng::LCG_A,
            thundering::prng::LCG_C,
        ));
    });
    b.run("jump/xs128_2^64", 1, || {
        black_box(thundering::prng::xorshift::xs128_jump(
            black_box([1, 2, 3, 4]),
            1u128 << 64,
        ));
    });
    b.run("jump/stream_jump_2^32", 1, || {
        let mut s = ThunderingStream::new(42, 0);
        s.jump(1 << 32);
        black_box(s.root_state());
    });
}
