//! Tile-execution benches: every AOT artifact through the PJRT runtime vs
//! the native batch engine at the same shape — the L2/L3 boundary cost
//! (dispatch + marshalling + execute). Feeds EXPERIMENTS.md §Perf.
//!
//! Run: `make artifacts && cargo bench --bench bench_tiles`

use thundering::prng::ThunderingBatch;
use thundering::runtime::{BsParams, Runtime, TileState};
use thundering::util::bench::{black_box, Bench};

fn artifacts_dir() -> String {
    std::env::var("THUNDERING_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")))
}

fn main() {
    let b = Bench::from_env();
    let rt = match Runtime::new(artifacts_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping tile benches (no artifacts): {e:#}");
            return;
        }
    };

    println!("# PJRT tile execution (numbers/iter = rows*p)");
    let mut names = rt.names_of_kind("thundering");
    names.extend(rt.names_of_kind("thundering_scan"));
    names.sort();
    for name in &names {
        let exe = rt.load(name).unwrap();
        let (rows, p) = (exe.info.rows, exe.info.p);
        let mut state = TileState::new(42, p, 0);
        let mut out = vec![0u32; rows * p];
        b.run(&format!("pjrt/{name}"), (rows * p) as u64, || {
            exe.run_thundering(&mut state, &mut out).unwrap();
            black_box(&out);
        });
    }

    println!("\n# native batch engine at matching shapes");
    for name in &names {
        let exe = rt.load(name).unwrap();
        let (rows, p) = (exe.info.rows, exe.info.p);
        let mut batch = ThunderingBatch::new(42, p, 0);
        let mut out = vec![0u32; rows * p];
        b.run(&format!("native/{name}"), (rows * p) as u64, || {
            batch.fill_rows(rows, &mut out);
            black_box(&out);
        });
    }

    println!("\n# baseline + app tiles");
    if let Ok(exe) = rt.load("philox_b1024_p64") {
        let (rows, p) = (exe.info.rows, exe.info.p);
        let mut out = vec![0u32; rows * p];
        let mut ctr = 0u64;
        b.run("pjrt/philox_b1024_p64", (rows * p) as u64, || {
            exe.run_philox(ctr, [7, 99], &mut out).unwrap();
            ctr += (rows / 4) as u64;
            black_box(&out);
        });
    }
    if let Ok(exe) = rt.load("pi_tile") {
        let p = exe.info.p;
        let draws = (exe.info.rows / 2 * p) as u64;
        let mut state = TileState::new(42, p, 0);
        b.run("pjrt/pi_tile", draws, || {
            black_box(exe.run_pi(&mut state).unwrap());
        });
    }
    if let Ok(exe) = rt.load("bs_tile") {
        let p = exe.info.p;
        let draws = (exe.info.rows / 2 * p) as u64;
        let mut state = TileState::new(42, p, 0);
        let params = BsParams::default();
        b.run("pjrt/bs_tile", draws, || {
            black_box(exe.run_bs(&mut state, &params).unwrap());
        });
    }
}
