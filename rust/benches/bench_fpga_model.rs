//! FPGA-model benches: cycle-level fabric simulation rate and full-sweep
//! report generation (Figs. 4/5/6, Table 5).
//!
//! Run: `cargo bench --bench bench_fpga_model`

use thundering::fpga::resources::ResourceModel;
use thundering::fpga::rsgu::{Rsgu, RsguDesign};
use thundering::fpga::sou::Fabric;
use thundering::fpga::throughput::{optimistic_scaling, thundering_throughput};
use thundering::util::bench::{black_box, Bench};

fn main() {
    let b = Bench::from_env();

    println!("# cycle-level RSGU simulation (states/iter)");
    b.run("rsgu/advance6_64k_states", 1 << 16, || {
        let mut r = Rsgu::new(RsguDesign::Advance6, 42);
        black_box(r.run(1 << 16));
    });
    b.run("rsgu/naive_8k_states", 1 << 13, || {
        let mut r = Rsgu::new(RsguDesign::NaiveDsp, 42);
        black_box(r.run(1 << 13));
    });

    println!("\n# cycle-level fabric simulation (output events/iter)");
    for n_sou in [16usize, 64, 256] {
        let cycles = 4096u64;
        let mut fab = Fabric::new(42, n_sou);
        let _ = fab.run(256); // warm the chain
        b.run(&format!("fabric/{n_sou}sou_4k_cycles"), cycles * n_sou as u64, || {
            black_box(fab.run(cycles));
        });
    }

    println!("\n# analytic sweeps (rows/iter)");
    let m = ResourceModel::default();
    b.run("model/fig5_sweep_2048pts", 2048, || {
        for n in 1..=2048u64 {
            black_box(m.fig5_row(n));
        }
    });
    b.run("model/fig6_sweep_2048pts", 2048, || {
        for n in 1..=2048u64 {
            black_box(thundering_throughput(&m, n));
        }
    });
    b.run("model/table5", 6, || {
        black_box(optimistic_scaling(&thundering::fpga::U250));
    });
}
