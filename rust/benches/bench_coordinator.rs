//! Coordinator-path benches: fetch hit/miss, group blocks, multi-client
//! scaling — the L3 hot path — plus the headline single-thread vs sharded
//! vs completion-front (`completion_overlap`) vs network-served
//! (`serve/loadgen`, 8 loopback TCP connections) GRN/s comparison,
//! emitted as a `BENCH_parallel.json` trajectory point.
//!
//! Run: `cargo bench --bench bench_coordinator`
//! (BENCH_ITERS=n adjusts iterations; BENCH_PARALLEL_OUT overrides the
//! JSON output path, default `BENCH_parallel.json`.)

use std::sync::Arc;

use thundering::serve::loadgen::{self, LoadgenConfig};
use thundering::serve::{ServeConfig, Server};
use thundering::util::bench::{black_box, Bench, JsonReport};
use thundering::{DistSpec, Engine, EngineBuilder, Request, StreamReq, StreamSource};

/// Server threads alive right now, by their `thng-` comm prefix — the
/// O(cores) half of the scaling claim. Linux-only (reads /proc).
#[cfg(target_os = "linux")]
fn thng_thread_count() -> usize {
    std::fs::read_dir("/proc/self/task")
        .map(|entries| {
            entries
                .filter_map(|e| {
                    let stat = std::fs::read_to_string(e.ok()?.path().join("stat")).ok()?;
                    let open = stat.find('(')?;
                    let close = stat.rfind(')')?;
                    stat[open + 1..close].starts_with("thng-").then_some(())
                })
                .count()
        })
        .unwrap_or(0)
}

#[cfg(not(target_os = "linux"))]
fn thng_thread_count() -> usize {
    0
}

fn native(streams: u64, width: usize, rows: usize) -> Box<dyn StreamSource> {
    EngineBuilder::new(streams)
        .engine(Engine::Native)
        .group_width(width)
        .rows_per_tile(rows)
        .lag_window(u64::MAX / 2)
        .build()
        .unwrap()
}

fn main() {
    let b = Bench::from_env();

    println!("# single-stream fetch (chunk = 4096 numbers)");
    {
        let c = native(64, 64, 1024);
        let mut buf = vec![0u32; 4096];
        b.run("fetch/native-64wide", 4096, || {
            c.fetch(0, &mut buf).unwrap();
            black_box(&buf);
        });
    }

    println!("\n# group block (1024 rows x 64 streams = 65536 numbers)");
    {
        let c = native(64, 64, 1024);
        b.run("fetch_block/native", 65536, || {
            black_box(c.fetch_block(0, 1024).unwrap());
        });
    }

    println!("\n# misaligned fetch (exercises buffering + pruning)");
    {
        let c = native(64, 64, 1024);
        let mut buf = vec![0u32; 1000]; // intentionally != tile multiple
        b.run("fetch/misaligned-1000", 1000, || {
            c.fetch(1, &mut buf).unwrap();
            black_box(&buf);
        });
    }

    println!("\n# concurrent clients (8 threads x 64k numbers each)");
    {
        let c: Arc<dyn StreamSource> = EngineBuilder::new(512)
            .engine(Engine::Native)
            .lag_window(u64::MAX / 2)
            .build_arc()
            .unwrap();
        b.run("fetch/concurrent-8", 8 * 65536, || {
            let handles: Vec<_> = (0..8u64)
                .map(|k| {
                    let c = c.clone();
                    std::thread::spawn(move || {
                        let mut buf = vec![0u32; 65536];
                        c.fetch(k * 64, &mut buf).unwrap();
                        black_box(&buf);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    // Tentpole comparison: one client draining every group through the
    // single-coordinator path (generation inline on the client thread —
    // one core total) vs the sharded engine (generation spread over one
    // shard per core, double-buffered ahead of the consumer; fetch_many
    // drains tile-granular in shard-affine order, so the caller's memcpy
    // overlaps generation).
    {
        let cores = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);
        let n_groups = cores.clamp(2, 16);
        let width = 64usize;
        let rows = 1024usize;
        let rounds = 8usize; // group blocks per measurement per group
        let numbers = (n_groups * rounds * rows * width) as u64;
        println!(
            "\n# single-thread vs sharded generation \
             ({n_groups} groups x {width} streams, {rounds} x {rows} rows/iter, {cores} cores)"
        );

        let single = native((n_groups * width) as u64, width, rows);
        let m_single = b.run("engine/single-thread", numbers, || {
            for _ in 0..rounds {
                for g in 0..n_groups {
                    black_box(single.fetch_block(g, rows).unwrap());
                }
            }
        });

        let sharded = EngineBuilder::new((n_groups * width) as u64)
            .engine(Engine::Sharded)
            .group_width(width)
            .rows_per_tile(rows)
            .lag_window(u64::MAX / 2)
            .build_sharded()
            .unwrap();
        let m_sharded = b.run("engine/sharded", numbers, || {
            for _ in 0..rounds {
                black_box(sharded.fetch_many(rows).unwrap());
            }
        });

        // Completion front: the same work driven by ONE consumer thread
        // with every group's block in flight through a CompletionQueue
        // (the worker shards complete tickets directly) — the overlap
        // the synchronous fetch_block loop cannot express.
        let completion = EngineBuilder::new((n_groups * width) as u64)
            .engine(Engine::Sharded)
            .group_width(width)
            .rows_per_tile(rows)
            .lag_window(u64::MAX / 2)
            .build_completion()
            .unwrap();
        let m_completion = b.run("engine/completion_overlap", numbers, || {
            for _ in 0..rounds {
                for g in 0..n_groups {
                    completion.submit(StreamReq::group(g, rows)).unwrap();
                }
            }
            for c in completion.wait_all(None) {
                black_box(c.result.unwrap());
            }
        });

        // Distribution shaping (DESIGN.md §7) on the same completion
        // front: rows/2 shaped rows at 2 raw draws each, so every
        // iteration consumes exactly the raw generation of
        // engine/completion_overlap and the throughput ratio is the
        // pure cost of shaping on the shard threads. Items stay counted
        // in raw-draw equivalents for that reason.
        let dist_rows = rows / 2;
        let dist_specs =
            [DistSpec::Normal { mean: 0.0, std: 1.0 }, DistSpec::Exponential { rate: 1.0 }];
        let m_dist: Vec<_> = dist_specs
            .iter()
            .map(|&spec| {
                b.run(&format!("engine/dist_{}", spec.name()), numbers, || {
                    for _ in 0..rounds {
                        for g in 0..n_groups {
                            completion
                                .submit(Request::group(g).rows(dist_rows).dist(spec))
                                .unwrap();
                        }
                    }
                    for c in completion.wait_all(None) {
                        black_box(c.result.unwrap());
                    }
                })
            })
            .collect();

        // Serving layer: the same engine behind loopback TCP, hammered
        // by 8 connections through the loadgen driver — what one
        // network hop plus framing costs relative to in-process drains
        // (DESIGN.md §6).
        let serve_source = EngineBuilder::new((n_groups * width) as u64)
            .engine(Engine::Sharded)
            .group_width(width)
            .rows_per_tile(rows)
            .lag_window(u64::MAX / 2)
            .build_arc()
            .unwrap();
        let server =
            Server::start(serve_source, "127.0.0.1:0", ServeConfig::default()).unwrap();
        let connections = 8usize;
        let fills = 8u32; // sequential fills per connection → latency samples
        let per_chunk = (rows * width) as u64;
        // Round the per-connection share up to whole fills of whole
        // chunks, exactly as loadgen does, so the exactly-once assert
        // below can demand a precise delivered count.
        let per_conn_chunks = (numbers / connections as u64)
            .max(1)
            .div_ceil(per_chunk)
            .div_ceil(u64::from(fills))
            * u64::from(fills);
        let served = per_conn_chunks * per_chunk * connections as u64;
        let loadgen_cfg = LoadgenConfig {
            addr: server.local_addr().to_string(),
            connections,
            numbers_per_conn: per_conn_chunks * per_chunk,
            chunk_rows: rows as u32,
            fills_per_conn: fills,
            ..LoadgenConfig::default()
        };
        let mut last_report = None;
        let m_serve = b.run("serve/loadgen", served, || {
            let report = loadgen::run(&loadgen_cfg).unwrap();
            assert_eq!(report.numbers, served, "exactly-once over TCP");
            last_report = Some(report);
        });
        drop(server);

        // Multi-tenant scaling: N short sessions (default 1000, override
        // with BENCH_SERVE_SESSIONS=n) against one readiness-loop server
        // with two weighted QoS classes — the scaling claim is that the
        // thread bill stays O(cores) while the session count grows two
        // orders of magnitude, with per-fill p99 staying sane. Run once,
        // not iterated: the report's own wall clock is the measurement.
        // Needs an open-files limit above ~2N (CI raises ulimit -n).
        let sessions: usize = std::env::var("BENCH_SERVE_SESSIONS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1000);
        let scale_rows = 256u32;
        let scale_fills = 4u32;
        let scale_per_conn = u64::from(scale_rows) * width as u64 * u64::from(scale_fills);
        let (scale_report, scale_threads) = {
            let scale_source = EngineBuilder::new((n_groups * width) as u64)
                .engine(Engine::Sharded)
                .group_width(width)
                .rows_per_tile(rows)
                .lag_window(u64::MAX / 2)
                .build_arc()
                .unwrap();
            let server = Server::start(
                scale_source,
                "127.0.0.1:0",
                ServeConfig { qos_weights: vec![(1, 4), (2, 1)], ..ServeConfig::default() },
            )
            .unwrap();
            let scale_cfg = LoadgenConfig {
                addr: server.local_addr().to_string(),
                connections: sessions,
                numbers_per_conn: scale_per_conn,
                chunk_rows: scale_rows,
                fills_per_conn: scale_fills,
                tags: vec![1, 2],
                ..LoadgenConfig::default()
            };
            let report = loadgen::run(&scale_cfg).unwrap();
            assert_eq!(
                report.numbers,
                scale_per_conn * sessions as u64,
                "exactly-once across {sessions} sessions"
            );
            let threads = thng_thread_count();
            (report, threads)
        };
        println!(
            "serve/scale: {sessions} sessions  {:.3} GRN/s  p50 = {:.2} ms  \
             p99 = {:.2} ms  server threads = {scale_threads}",
            scale_report.grn_per_s(),
            scale_report.latency_percentile(50.0) * 1e3,
            scale_report.latency_percentile(99.0) * 1e3,
        );

        let speedup = m_sharded.throughput() / m_single.throughput();
        let overlap_speedup = m_completion.throughput() / m_single.throughput();
        println!(
            "single-thread = {:.3} GRN/s  sharded = {:.3} GRN/s  speedup = {speedup:.2}x \
             ({} shards)  completion-front = {:.3} GRN/s ({overlap_speedup:.2}x, 1 consumer)  \
             serve/loadgen = {:.3} GRN/s ({connections} TCP conns)",
            m_single.throughput() / 1e9,
            m_sharded.throughput() / 1e9,
            sharded.n_shards(),
            m_completion.throughput() / 1e9,
            m_serve.throughput() / 1e9,
        );

        let mut rep = JsonReport::new();
        rep.context_str("bench", "parallel-generation");
        rep.context_num("cores", cores as f64);
        rep.context_num("shards", sharded.n_shards() as f64);
        rep.context_num("n_groups", n_groups as f64);
        rep.context_num("group_width", width as f64);
        rep.context_num("rows_per_tile", rows as f64);
        rep.context_num("single_thread_grn_per_s", m_single.throughput() / 1e9);
        rep.context_num("sharded_grn_per_s", m_sharded.throughput() / 1e9);
        rep.context_num("completion_overlap_grn_per_s", m_completion.throughput() / 1e9);
        rep.context_num("speedup", speedup);
        rep.context_num("completion_overlap_speedup", overlap_speedup);
        rep.context_num("serve_loadgen_grn_per_s", m_serve.throughput() / 1e9);
        rep.context_num("serve_connections", connections as f64);
        // Shaped-vs-raw on the completion front, in raw-draw GRN/s; the
        // ratio (> 1) is what shaping costs at equal raw generation.
        for (spec, m) in dist_specs.iter().zip(&m_dist) {
            rep.context_num(
                &format!("dist_{}_grn_per_s", spec.name()),
                m.throughput() / 1e9,
            );
            rep.context_num(
                &format!("dist_{}_overhead_ratio", spec.name()),
                m_completion.throughput() / m.throughput(),
            );
        }
        // Per-fill service latency through the full serving stack
        // (submit → final chunk over loopback TCP), from the last
        // loadgen run — the QoS numbers the deadline story is about.
        if let Some(lg) = &last_report {
            rep.context_num("serve_fill_p50_ms", lg.latency_percentile(50.0) * 1e3);
            rep.context_num("serve_fill_p95_ms", lg.latency_percentile(95.0) * 1e3);
            rep.context_num("serve_fill_p99_ms", lg.latency_percentile(99.0) * 1e3);
            rep.context_num("serve_fills_sampled", lg.fill_latencies_s.len() as f64);
        }
        // The multi-tenant scaling point: N sessions through O(cores)
        // server threads, with the fair-drain p50/p99 across two QoS
        // classes. `serve_scale_threads` is 0 off-Linux (no /proc).
        rep.context_num("serve_scale_sessions", sessions as f64);
        rep.context_num("serve_scale_grn_per_s", scale_report.grn_per_s());
        rep.context_num("serve_scale_p50_ms", scale_report.latency_percentile(50.0) * 1e3);
        rep.context_num("serve_scale_p99_ms", scale_report.latency_percentile(99.0) * 1e3);
        rep.context_num("serve_scale_threads", scale_threads as f64);
        rep.push(&m_single);
        rep.push(&m_sharded);
        rep.push(&m_completion);
        for m in &m_dist {
            rep.push(m);
        }
        rep.push(&m_serve);
        let out = std::env::var("BENCH_PARALLEL_OUT")
            .unwrap_or_else(|_| "BENCH_parallel.json".to_string());
        match rep.write(&out) {
            Ok(()) => println!("wrote {out}"),
            Err(e) => eprintln!("could not write {out}: {e}"),
        }
    }

    // PJRT path if artifacts exist.
    let art = std::env::var("THUNDERING_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")));
    if std::path::Path::new(&art).join("manifest.json").exists() {
        println!("\n# PJRT-backed coordinator");
        let c = EngineBuilder::new(64)
            .engine(Engine::Pjrt { artifacts_dir: art })
            .group_width(64)
            .rows_per_tile(1024)
            .lag_window(u64::MAX / 2)
            .build()
            .unwrap();
        b.run("fetch_block/pjrt", 65536, || {
            black_box(c.fetch_block(0, 1024).unwrap());
        });
        let mut buf = vec![0u32; 4096];
        b.run("fetch/pjrt-4096", 4096, || {
            c.fetch(0, &mut buf).unwrap();
            black_box(&buf);
        });
    }
}
