//! Coordinator-path benches: fetch hit/miss, group blocks, multi-client
//! scaling — the L3 hot path (EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench bench_coordinator`

use std::sync::Arc;

use thundering::coordinator::{Config, Coordinator, Engine};
use thundering::util::bench::{black_box, Bench};

fn native(streams: u64, width: usize, rows: usize) -> Coordinator {
    Coordinator::new(
        Config {
            engine: Engine::Native,
            group_width: width,
            rows_per_tile: rows,
            lag_window: u64::MAX / 2,
            ..Default::default()
        },
        streams,
    )
    .unwrap()
}

fn main() {
    let b = Bench::from_env();

    println!("# single-stream fetch (chunk = 4096 numbers)");
    {
        let c = native(64, 64, 1024);
        let mut buf = vec![0u32; 4096];
        b.run("fetch/native-64wide", 4096, || {
            c.fetch(0, &mut buf).unwrap();
            black_box(&buf);
        });
    }

    println!("\n# group block (1024 rows x 64 streams = 65536 numbers)");
    {
        let c = native(64, 64, 1024);
        b.run("fetch_block/native", 65536, || {
            black_box(c.fetch_group_block(0, 1024).unwrap());
        });
    }

    println!("\n# misaligned fetch (exercises buffering + pruning)");
    {
        let c = native(64, 64, 1024);
        let mut buf = vec![0u32; 1000]; // intentionally != tile multiple
        b.run("fetch/misaligned-1000", 1000, || {
            c.fetch(1, &mut buf).unwrap();
            black_box(&buf);
        });
    }

    println!("\n# concurrent clients (8 threads x 64k numbers each)");
    {
        let c = Arc::new(native(512, 64, 1024));
        b.run("fetch/concurrent-8", 8 * 65536, || {
            let handles: Vec<_> = (0..8u64)
                .map(|k| {
                    let c = c.clone();
                    std::thread::spawn(move || {
                        let mut buf = vec![0u32; 65536];
                        c.fetch(k * 64, &mut buf).unwrap();
                        black_box(&buf);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    // PJRT path if artifacts exist.
    let art = std::env::var("THUNDERING_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")));
    if std::path::Path::new(&art).join("manifest.json").exists() {
        println!("\n# PJRT-backed coordinator");
        let c = Coordinator::new(
            Config {
                engine: Engine::Pjrt { artifacts_dir: art },
                group_width: 64,
                rows_per_tile: 1024,
                lag_window: u64::MAX / 2,
                ..Default::default()
            },
            64,
        )
        .unwrap();
        b.run("fetch_block/pjrt", 65536, || {
            black_box(c.fetch_group_block(0, 1024).unwrap());
        });
        let mut buf = vec![0u32; 4096];
        b.run("fetch/pjrt-4096", 4096, || {
            c.fetch(0, &mut buf).unwrap();
            black_box(&buf);
        });
    }
}
