//! Application benches — the measured core of Figs. 8/9: π estimation and
//! option pricing through the engine-agnostic `run(&dyn StreamSource)`
//! driver (native and sharded engines) and on the PJRT AOT tiles.
//!
//! Run: `make artifacts && cargo bench --bench bench_apps`

use thundering::apps::{option_pricing, pi};
use thundering::runtime::executor::TileExecutor;
use thundering::runtime::BsParams;
use thundering::util::bench::{black_box, Bench};
use thundering::{Engine, EngineBuilder, StreamSource};

fn main() {
    let b = Bench::from_env();
    let threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(8);
    let draws: u64 = 1 << 24;

    let source = |engine: Engine| -> Box<dyn StreamSource> {
        EngineBuilder::new(threads as u64 * 64).engine(engine).build().unwrap()
    };

    println!("# engine-agnostic driver ({draws} draws/iter, {threads} consumer groups)");
    {
        let native = source(Engine::Native);
        b.run("pi/native", draws, || {
            black_box(pi::run(&*native, draws).unwrap());
        });
        b.run("bs/native", draws, || {
            black_box(option_pricing::run(&*native, draws, BsParams::default()).unwrap());
        });
    }
    {
        let sharded = source(Engine::Sharded);
        b.run("pi/sharded", draws, || {
            black_box(pi::run(&*sharded, draws).unwrap());
        });
        b.run("bs/sharded", draws, || {
            black_box(option_pricing::run(&*sharded, draws, BsParams::default()).unwrap());
        });
    }

    let art = std::env::var("THUNDERING_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")));
    if !std::path::Path::new(&art).join("manifest.json").exists() {
        eprintln!("skipping PJRT app benches (no artifacts)");
        return;
    }
    let guard = TileExecutor::spawn(art, 4).unwrap();

    println!("\n# PJRT AOT tile engine ({draws} draws/iter)");
    b.run("pi/pjrt", draws, || {
        black_box(pi::run_pjrt(&guard.executor, draws, 42).unwrap());
    });
    b.run("bs/pjrt", draws, || {
        black_box(
            option_pricing::run_pjrt(&guard.executor, draws, 42, BsParams::default()).unwrap(),
        );
    });

    println!("\n# scalar single-stream baselines (2^22 draws/iter)");
    let small = 1u64 << 22;
    b.run("pi/scalar-thundering", small, || {
        let mut g = thundering::prng::ThunderingStream::new(42, 0);
        black_box(pi::run_scalar(&mut g, small));
    });
    b.run("pi/scalar-philox", small, || {
        let mut g = thundering::prng::Philox4x32::new([7, 99]);
        black_box(pi::run_scalar(&mut g, small));
    });
}
