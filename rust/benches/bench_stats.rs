//! Statistical-battery benches: per-test costs at battery sizes. The
//! battery dominates the Table 2 runtime, so these locate its hot spots.
//!
//! Run: `cargo bench --bench bench_stats`

use thundering::prng::SplitMix64;
use thundering::stats::{birthday, corr, freq, hwd, lincomp, rank, serial};
use thundering::util::bench::{black_box, Bench};

fn main() {
    let b = Bench::from_env();
    println!("# battery test costs (items = samples consumed)");
    b.run("stats/monobit_1M", 1 << 20, || {
        let mut g = SplitMix64::new(1);
        black_box(freq::monobit(&mut g, 1 << 20));
    });
    b.run("stats/serial_m8_256k", 1 << 18, || {
        let mut g = SplitMix64::new(2);
        black_box(serial::serial(&mut g, 8, 1 << 18));
    });
    b.run("stats/poker_m4_256k", 1 << 18, || {
        let mut g = SplitMix64::new(3);
        black_box(serial::poker(&mut g, 4, 1 << 18));
    });
    b.run("stats/collision_64k", 1 << 16, || {
        let mut g = SplitMix64::new(4);
        black_box(serial::collision(&mut g, 24, 1 << 16));
    });
    b.run("stats/birthday_2k_x4", (2048 * 4) as u64, || {
        let mut g = SplitMix64::new(5);
        black_box(birthday::birthday_spacings(&mut g, 2048, 28, 4));
    });
    b.run("stats/rank64_256mats", (64 * 64 * 256 / 32) as u64, || {
        let mut g = SplitMix64::new(6);
        black_box(rank::matrix_rank(&mut g, 64, 256));
    });
    b.run("stats/rank256_16mats", (256 * 256 * 16 / 32) as u64, || {
        let mut g = SplitMix64::new(7);
        black_box(rank::matrix_rank(&mut g, 256, 16));
    });
    b.run("stats/berlekamp_massey_4k", 4096, || {
        let mut g = SplitMix64::new(8);
        black_box(lincomp::linear_complexity(&mut g, 0, 4096));
    });
    b.run("stats/hwd_multilag_256k", 1 << 18, || {
        let mut g = SplitMix64::new(9);
        black_box(hwd::hwd_multilag(&mut g, 1 << 18, 4));
    });
    b.run("stats/correlations_16k", (3 * 16384) as u64, || {
        let mut a = SplitMix64::new(10);
        let mut bgen = SplitMix64::new(11);
        black_box(corr::correlations(&mut a, &mut bgen, 1 << 14));
    });
}
