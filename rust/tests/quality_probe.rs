//! Quality at non-origin stream positions: the battery must hold anywhere
//! in the sequence, since the coordinator serves arbitrary offsets (this
//! is also the regression test for the p≈1 verdict-saturation bug: a
//! dead-center collision count once misread as a failure).

use thundering::prng::{splitmix64, ThunderingStream};
use thundering::stats::{mini_crush, Scale};

#[test]
fn battery_passes_at_deep_offsets() {
    for offset in [65536u64, 1 << 24, 1 << 40] {
        let mut s = ThunderingStream::new(splitmix64(42), 1);
        s.jump(offset);
        let rep = mini_crush(&mut s, Scale::Quick);
        assert_eq!(rep.failures(), 0, "offset {offset}: {}", rep.summary());
    }
}
