//! Quality at non-origin stream positions: the battery must hold anywhere
//! in the sequence, since the coordinator serves arbitrary offsets (this
//! is also the regression test for the p≈1 verdict-saturation bug: a
//! dead-center collision count once misread as a failure).
//!
//! Plus goodness-of-fit probes for the distribution shaping layer
//! (DESIGN.md §7): every continuous sampler must pass a KS test after
//! the probability integral transform through its analytic CDF, every
//! discrete sampler a Pearson chi-square test against its pmf.

use thundering::dist::{decode_f64, shape_words};
use thundering::prng::{splitmix64, Prng32, ThunderingStream};
use thundering::stats::special::{chi2_test, ks_test_uniform, ln_gamma, normal_sf};
use thundering::stats::{mini_crush, Scale};
use thundering::DistSpec;

#[test]
fn battery_passes_at_deep_offsets() {
    for offset in [65536u64, 1 << 24, 1 << 40] {
        let mut s = ThunderingStream::new(splitmix64(42), 1);
        s.jump(offset);
        let rep = mini_crush(&mut s, Scale::Quick);
        assert_eq!(rep.failures(), 0, "offset {offset}: {}", rep.summary());
    }
}

/// Samples per goodness-of-fit probe. Fixed seeds make the p-values
/// deterministic; the 1e-6 gate leaves no room for flakiness.
const GOF_N: usize = 1 << 16;
const GOF_GATE: f64 = 1e-6;

/// `n` shaped f64 samples of `spec` from one MISRN stream.
fn shaped_f64(spec: DistSpec, seed: u64, n: usize) -> Vec<f64> {
    decode_f64(&shaped_words(spec, seed, n))
}

fn shaped_words(spec: DistSpec, seed: u64, n: usize) -> Vec<u32> {
    let mut s = ThunderingStream::new(splitmix64(seed), 0);
    let raw: Vec<u32> = (0..n * spec.draws_per_row()).map(|_| s.next_u32()).collect();
    shape_words(spec, &raw, 1)
}

/// KS after the probability integral transform: `cdf(x)` of a correct
/// sampler is U(0,1).
fn assert_ks(spec: DistSpec, seed: u64, cdf: impl Fn(f64) -> f64) {
    let mut u: Vec<f64> = shaped_f64(spec, seed, GOF_N).into_iter().map(cdf).collect();
    u.sort_by(f64::total_cmp);
    let p = ks_test_uniform(&u);
    assert!(p > GOF_GATE, "{spec}: KS p = {p:.3e}");
}

#[test]
fn continuous_samplers_pass_ks() {
    assert_ks(DistSpec::Uniform01, 101, |x| x);
    let (lo, hi) = (-3.0, 7.0);
    assert_ks(DistSpec::UniformRange { lo, hi }, 102, |x| (x - lo) / (hi - lo));
    let (mean, std) = (1.5, 2.0);
    assert_ks(DistSpec::Normal { mean, std }, 103, |x| {
        1.0 - normal_sf((x - mean) / std)
    });
    let rate = 0.75;
    assert_ks(DistSpec::Exponential { rate }, 104, |x| 1.0 - (-rate * x).exp());
}

#[test]
fn bernoulli_passes_chi2() {
    let p = 0.3;
    let words = shaped_words(DistSpec::Bernoulli { p }, 105, GOF_N);
    let ones = words.iter().filter(|&&w| w == 1).count();
    assert_eq!(
        words.iter().filter(|&&w| w > 1).count(),
        0,
        "Bernoulli output must be 0/1"
    );
    let n = GOF_N as f64;
    let observed = [(GOF_N - ones) as f64, ones as f64];
    let expected = [n * (1.0 - p), n * p];
    let (stat, pval) = chi2_test(&observed, &expected);
    assert!(pval > GOF_GATE, "Bernoulli chi2 = {stat:.2}, p = {pval:.3e}");
}

#[test]
fn poisson_passes_chi2() {
    let rate = 4.0;
    let words = shaped_words(DistSpec::Poisson { rate }, 106, GOF_N);
    // Bins 0..=12 plus one ≥13 tail bin; at λ=4 and 64k samples every
    // expected count clears the >5 rule chi2_test assumes.
    const BINS: usize = 13;
    let mut observed = [0f64; BINS + 1];
    for &w in &words {
        observed[(w as usize).min(BINS)] += 1.0;
    }
    let n = GOF_N as f64;
    let mut expected = [0f64; BINS + 1];
    let mut head = 0.0;
    for (k, e) in expected.iter_mut().enumerate().take(BINS) {
        let pmf =
            (f64::from(k as u32) * rate.ln() - rate - ln_gamma(k as f64 + 1.0)).exp();
        *e = n * pmf;
        head += pmf;
    }
    expected[BINS] = n * (1.0 - head);
    let (stat, pval) = chi2_test(&observed, &expected);
    assert!(pval > GOF_GATE, "Poisson chi2 = {stat:.2}, p = {pval:.3e}");
}
