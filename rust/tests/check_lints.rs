//! Fixture and live-tree tests for the `thng-check` static-analysis
//! pass (ISSUE 8). Each lint has at least one *failing* fixture — a
//! lint that cannot fail is a lint that silently stopped working — and
//! a passing one showing the sanctioned idiom. The meta-tests then run
//! the real pass over `rust/src` and pin it to the committed
//! `LINT.json` baseline.

use std::path::Path;

use thundering::check::{
    analyze_source, analyze_tree, baseline_drift, regressions_vs_baseline, Lint, Report,
};

/// Scan fixture text under a chosen relative path (lint scoping is
/// path-based, so the same fixture can probe in- and out-of-scope).
fn scan(rel: &str, src: &str) -> Vec<thundering::check::Finding> {
    analyze_source(rel, src).0
}

fn count(findings: &[thundering::check::Finding], lint: Lint, justified: bool) -> usize {
    findings.iter().filter(|f| f.lint == lint && f.justified == justified).count()
}

// ---------------------------------------------------------------------------
// per-lint fixtures

#[test]
fn panic_fixture_fails_and_pass_variant_is_clean() {
    let fail = scan("serve/frame.rs", include_str!("check_fixtures/panic_fail.rs"));
    assert_eq!(count(&fail, Lint::Panic, false), 5, "{fail:?}");

    let pass = scan("serve/frame.rs", include_str!("check_fixtures/panic_pass.rs"));
    assert_eq!(count(&pass, Lint::Panic, false), 0, "{pass:?}");
    assert_eq!(count(&pass, Lint::Panic, true), 1, "the pragma'd expect is justified");
    // The same text outside the policy scope raises nothing.
    let out = scan("prng/frame.rs", include_str!("check_fixtures/panic_fail.rs"));
    assert_eq!(count(&out, Lint::Panic, false), 0);
}

#[test]
fn index_fixture_is_advisory_only() {
    let f = scan("serve/frame.rs", include_str!("check_fixtures/index_advisory.rs"));
    assert_eq!(count(&f, Lint::Index, false), 4, "{f:?}");
    assert!(Lint::Index.advisory() && !Lint::Panic.advisory());
}

#[test]
fn lock_order_fixture_fails_on_descending_nesting_only() {
    let fail = scan("serve/session.rs", include_str!("check_fixtures/lock_order_fail.rs"));
    assert_eq!(count(&fail, Lint::LockOrder, false), 1, "{fail:?}");

    let pass = scan("serve/session.rs", include_str!("check_fixtures/lock_order_pass.rs"));
    assert_eq!(count(&pass, Lint::LockOrder, false), 0, "{pass:?}");
}

#[test]
fn thread_name_fixture_fails_all_three_ways() {
    let fail = scan("util/spawn.rs", include_str!("check_fixtures/thread_name_fail.rs"));
    assert_eq!(count(&fail, Lint::ThreadName, false), 3, "{fail:?}");

    let pass = scan("util/spawn.rs", include_str!("check_fixtures/thread_name_pass.rs"));
    assert_eq!(count(&pass, Lint::ThreadName, false), 0, "{pass:?}");
}

#[test]
fn determinism_fixture_fails_in_replay_scope_only() {
    let fail = scan("dist/shape.rs", include_str!("check_fixtures/determinism_fail.rs"));
    assert_eq!(count(&fail, Lint::Determinism, false), 3, "{fail:?}");

    // Deadline arithmetic outside the replay paths is legitimate.
    let out = scan("serve/shape.rs", include_str!("check_fixtures/determinism_fail.rs"));
    assert_eq!(count(&out, Lint::Determinism, false), 0, "{out:?}");

    let pass = scan("dist/shape.rs", include_str!("check_fixtures/determinism_pass.rs"));
    assert_eq!(count(&pass, Lint::Determinism, false), 0, "{pass:?}");
}

#[test]
fn unranked_lock_fixture_fails_in_the_core_only() {
    let fail = scan("coordinator/cache.rs", include_str!("check_fixtures/unranked_lock_fail.rs"));
    assert_eq!(count(&fail, Lint::UnrankedLock, false), 2, "{fail:?}");

    let out = scan("stats/cache.rs", include_str!("check_fixtures/unranked_lock_fail.rs"));
    assert_eq!(count(&out, Lint::UnrankedLock, false), 0, "{out:?}");

    let pass = scan("coordinator/cache.rs", include_str!("check_fixtures/unranked_lock_pass.rs"));
    assert_eq!(count(&pass, Lint::UnrankedLock, false), 0, "{pass:?}");
}

#[test]
fn wait_held_fixture_fails_on_the_second_lock_only() {
    let fail = scan("serve/session.rs", include_str!("check_fixtures/wait_held_fail.rs"));
    assert_eq!(count(&fail, Lint::WaitHeld, false), 2, "{fail:?}");

    let pass = scan("serve/session.rs", include_str!("check_fixtures/wait_held_pass.rs"));
    assert_eq!(count(&pass, Lint::WaitHeld, false), 0, "{pass:?}");
    // The fixtures park in declared order — the wait audit is the only
    // thing separating them.
    assert_eq!(count(&fail, Lint::LockOrder, false), 0, "{fail:?}");
}

#[test]
fn pragma_fixture_fails_every_malformed_shape() {
    let f = scan("serve/any.rs", include_str!("check_fixtures/pragma_fail.rs"));
    assert_eq!(count(&f, Lint::Pragma, false), 3, "{f:?}");
}

// ---------------------------------------------------------------------------
// live tree

fn live_report() -> Report {
    let root = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/rust/src"));
    analyze_tree(root).expect("rust/src scans")
}

/// Acceptance gate: the pass runs clean over its own repository — zero
/// unjustified deny-level findings, exactly the committed pragmas.
#[test]
fn live_tree_is_clean() {
    let report = live_report();
    let offenders: Vec<_> = report
        .findings
        .iter()
        .filter(|f| !f.justified && !f.lint.advisory())
        .map(|f| format!("{}:{} [{}] {}", f.file, f.line, f.lint.name(), f.msg))
        .collect();
    assert!(offenders.is_empty(), "unjustified findings:\n{}", offenders.join("\n"));
    assert_eq!(report.deny_total(), 0);
}

/// The committed `LINT.json` matches the tree: deny counts and the
/// pragma trajectory exactly (drift), and the advisory slice-index
/// census at or under its recorded ratchet ceiling (regressions). The
/// ceiling is a ratchet, not an exact count — slack under it is fine;
/// regenerate with `cargo run --bin thng-check -- --write-baseline
/// LINT.json` whenever a pragma is added or retired, or to tighten the
/// ceiling to the live census.
#[test]
fn committed_baseline_matches_the_tree() {
    let report = live_report();
    let committed = include_str!(concat!(env!("CARGO_MANIFEST_DIR"), "/LINT.json"));
    let drift = baseline_drift(&report, committed);
    assert!(drift.is_empty(), "LINT.json is stale:\n{}", drift.join("\n"));
    let regs = regressions_vs_baseline(&report, committed);
    assert!(regs.is_empty(), "regressions vs LINT.json:\n{}", regs.join("\n"));
}
