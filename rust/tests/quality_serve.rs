//! End-to-end quality-harness test (ISSUE 10): an in-process server per
//! engine, scored by the real collection client — 256 remote streams
//! across 8 concurrent TCP sessions, each stream fetched in multiple
//! chunked FILLs — then the full cross-stream battery over the words
//! that actually crossed the wire. A shrunken (but `validate`d) profile
//! keeps the debug-build runtime in CI territory; the shipped `ci`
//! profile runs against a release server in the CI quality job.

use thundering::quality::{self, HarnessConfig, Profile};
use thundering::serve::{ServeConfig, Server};
use thundering::{Engine, EngineBuilder};

/// `ci` with every budget shrunk ~4x — still four tests; the harness's
/// chunk cap is shrunk alongside (256 words) so each 1024-word stream
/// still takes 4 FILL round-trips through the wire chunking path.
fn shrunken_profile() -> Profile {
    let mut p = Profile::ci();
    p.name = "ci-shrunk".into();
    p.samples_per_stream = 1024;
    p.pair_budget = 64;
    p.corr_n = 1024;
    p.birthday_m = 2048;
    p.birthday_t = 26;
    p.birthday_reps = 4;
    p.rank_nmat = 128;
    p.hwd_n = 1024;
    p.hwd_maxlag = 4;
    p.validate().expect("shrunken profile is internally consistent");
    p
}

fn score_engine(engine: Engine, expect_kind: &str) {
    let source = EngineBuilder::new(256)
        .engine(engine)
        .group_width(32)
        .lag_window(u64::MAX / 2)
        .build_arc()
        .expect("engine builds");
    let mut server =
        Server::start(source, "127.0.0.1:0", ServeConfig::default()).expect("server starts");
    let addr = server.local_addr().to_string();

    let mut cfg = HarnessConfig::new(&addr);
    cfg.streams = 256;
    cfg.sessions = 8;
    cfg.connect_attempts = 3;
    cfg.max_chunk = 256; // 4 FILLs per stream: chunking + per-lease continuation
    let report = quality::run_remote(&cfg, &shrunken_profile()).expect("harness scores");
    server.shutdown();

    assert!(report.passed(), "[{expect_kind}] battery failed: {}", report.summary());
    assert_eq!(report.engine, expect_kind, "engine kind rides the HELLO into the report");
    assert_eq!(report.streams, 256);
    assert_eq!(report.sessions, 8);
    assert_eq!(report.results.len(), 4);
    assert_eq!(report.pairs_scored, 64, "budget-capped schedule");
    assert_eq!(report.pairs_total, 256 * 255 / 2);
    assert!(report.pairs_dropped() > 0, "dropped pairs are reported, not hidden");
}

#[test]
fn remote_battery_passes_on_the_native_engine() {
    score_engine(Engine::Native, "native");
}

#[test]
fn remote_battery_passes_on_the_sharded_engine() {
    score_engine(Engine::Sharded, "sharded");
}

#[test]
fn harness_rejects_oversubscription_with_a_typed_error() {
    let source = EngineBuilder::new(64)
        .engine(Engine::Native)
        .group_width(32)
        .build_arc()
        .expect("engine builds");
    let mut server =
        Server::start(source, "127.0.0.1:0", ServeConfig::default()).expect("server starts");
    let addr = server.local_addr().to_string();

    let mut cfg = HarnessConfig::new(&addr);
    cfg.streams = 128; // server only has 64
    let err = quality::collect_remote(&cfg, 64).unwrap_err();
    server.shutdown();
    assert!(
        matches!(err, thundering::Error::InvalidConfig(_)),
        "oversubscription is a config error, got {err:?}"
    );
}
