//! PASS fixture (scanned as `serve/session.rs`): the sanctioned parking
//! idioms — wait with only the wait's own guard held (atomically
//! released), wait after the second lock is dropped, and the timeout
//! variants under the same discipline.

pub fn drain(sess: &Session, cv: &Condvar) {
    let mut st = sess.lock();
    st = st.wait(&cv);
    drop(st);
}

pub fn drain_after_release(server: &Server, sess: &Session, cv: &Condvar, timeout: Duration) {
    let routes = server.lock_routes();
    drop(routes);
    let mut st = sess.lock();
    st = st.wait_timeout(&cv, timeout);
    st = st.wait_timeout_checked(&cv, timeout);
    drop(st);
}
