//! PASS fixture (scanned as `util/spawn.rs`): named `thng-` Builder
//! spawns, literal and formatted.

pub fn start(i: usize) {
    let a = std::thread::Builder::new()
        .name(format!("thng-w{i}"))
        .spawn(|| {});
    let b = std::thread::Builder::new()
        .name("thng-fixed".into())
        .spawn(|| {});
}
