//! PASS fixture (scanned as `serve/frame.rs`): typed errors, one
//! justified pragma, and test-only unwraps behind `#[cfg(test)]`.

pub fn decode(buf: &[u8]) -> Result<u32, Error> {
    let head = buf.first().ok_or(Error::Short)?;
    // thng: allow(panic, "invariant: caller validated the length above")
    let tail = buf.last().expect("non-empty");
    Ok(u32::from(*head) + u32::from(*tail))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unwrap_is_fine_in_tests() {
        decode(&[1, 2]).unwrap();
    }
}
