//! FAIL fixture (scanned as `serve/session.rs`): the condvar wait parks
//! while `routes` (rank 10) is still held — the wait atomically
//! releases only its own `session` guard, so a notifier that needs
//! `routes` deadlocks against the sleeper.

pub fn drain(server: &Server, sess: &Session, cv: &Condvar) {
    let routes = server.lock_routes();
    let mut st = sess.lock();
    st = st.wait(&cv);
    drop(st);
    drop(routes);
}

pub fn drain_timeout(server: &Server, sess: &Session, cv: &Condvar, timeout: Duration) {
    let routes = server.lock_routes();
    let mut st = sess.lock();
    st = st.wait_timeout_checked(&cv, timeout);
    drop(st);
    drop(routes);
}
