//! FAIL fixture (scanned as `serve/frame.rs`): every panic-family site
//! below is an unjustified deny finding.

pub fn decode(buf: &[u8]) -> u32 {
    let head = buf.first().unwrap();
    let tail = buf.last().expect("non-empty");
    if *head == 0 {
        panic!("zero header");
    }
    if *tail == 0 {
        unreachable!();
    }
    todo!()
}
