//! PASS fixture (scanned as `dist/shape.rs`): pure arithmetic on an
//! explicit seed — nothing environmental.

pub fn sample(seed: u64) -> u64 {
    seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407)
}
