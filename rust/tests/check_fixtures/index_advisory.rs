//! Advisory fixture (scanned as `serve/frame.rs`): slice indexing is
//! reported but never gates a run.

pub fn word(buf: &[u8]) -> u32 {
    u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]])
}
