//! FAIL fixture (scanned as `coordinator/cache.rs`): raw std::sync
//! lock construction where the ranked facade is mandatory.

pub fn build() -> (Mutex<u64>, RwLock<Vec<u8>>) {
    (Mutex::new(0), RwLock::new(Vec::new()))
}
