//! PASS fixture (scanned as `serve/session.rs`): the same two locks in
//! declared order, plus an early drop and a temporary guard.

pub fn visit(server: &Server, sess: &Session) {
    let routes = server.lock_routes();
    let st = sess.lock();
    drop(st);
    drop(routes);
}

pub fn peek(server: &Server, sess: &Session) {
    let n = sess.lock().queue_len();
    {
        let st = sess.lock();
        drop(st);
    }
    let routes = server.lock_routes();
    drop(routes);
}
