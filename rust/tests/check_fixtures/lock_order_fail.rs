//! FAIL fixture (scanned as `serve/session.rs`): `session` (rank 20)
//! is held while `routes` (rank 10) is acquired — descending nesting.

pub fn visit(server: &Server, sess: &Session) {
    let st = sess.lock();
    let routes = server.lock_routes();
    drop(routes);
    drop(st);
}
