//! FAIL fixture (scanned as `util/spawn.rs`): three thread-discipline
//! violations — raw spawn, unnamed Builder, name without the prefix.

pub fn start() {
    std::thread::spawn(|| {});
    let a = std::thread::Builder::new().spawn(|| {});
    let b = std::thread::Builder::new()
        .name("worker-1".into())
        .spawn(|| {});
}
