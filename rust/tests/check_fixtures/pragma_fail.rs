//! FAIL fixture (any path): three malformed pragmas — each is itself a
//! deny finding so a broken suppression cannot rot silently.

pub fn f() {
    // thng: allow(panic)
    // thng: allow(frobnicate, "no such lint")
    // thng: deny(panic, "unknown directive")
}
