//! PASS fixture (scanned as `coordinator/cache.rs`): the ranked facade
//! with a declared rank.

use crate::check::lock_order::INBOX;
use crate::sync::OrderedMutex;

pub fn build() -> OrderedMutex<u64> {
    OrderedMutex::new(&INBOX, 0)
}
