//! FAIL fixture (scanned as `dist/shape.rs` — a replay-critical path):
//! three wall-clock/environment reads.

pub fn sample() -> u64 {
    let t = std::time::Instant::now();
    let epoch = std::time::SystemTime::now();
    let seed = std::env::var("THNG_SEED");
    0
}
