//! Serving-layer loopback integration: determinism over the wire (the
//! bytes a client reads are exactly the scalar replay, on both
//! engines), typed errors across the boundary, chunked-fill
//! exactly-once ordering, the BYE flush contract, malformed-frame
//! rejection, and the multi-connection loadgen driver.

use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use thundering::prng::{splitmix64, Prng32, ThunderingBatch, ThunderingStream};
use thundering::serve::loadgen::{self, LoadgenConfig};
use thundering::serve::protocol::{self, Frame};
use thundering::serve::{RemoteClient, RemoteSource, ServeConfig, Server};
use thundering::{
    Engine, EngineBuilder, Error, ReqTarget, Request, StreamHandle, StreamSource,
};

/// A source with the test shape: `groups × width` streams, seed 42.
fn source(
    engine: Engine,
    groups: usize,
    width: usize,
    rows_per_tile: usize,
    lag_window: u64,
) -> Arc<dyn StreamSource> {
    EngineBuilder::new((groups * width) as u64)
        .engine(engine)
        .group_width(width)
        .rows_per_tile(rows_per_tile)
        .lag_window(lag_window)
        .root_seed(42)
        .build_arc()
        .unwrap()
}

fn serve(src: Arc<dyn StreamSource>) -> Server {
    Server::start(src, "127.0.0.1:0", ServeConfig::default()).unwrap()
}

/// Scalar oracle: rows `skip..skip+rows` of one group's block.
fn oracle_block(group: u64, width: usize, skip: usize, rows: usize) -> Vec<u32> {
    let mut batch = ThunderingBatch::new(splitmix64(42 ^ group), width, group * width as u64);
    if skip > 0 {
        batch.tile(skip);
    }
    batch.tile(rows)
}

#[test]
fn remote_source_is_bit_identical_to_local_on_both_engines() {
    for engine in [Engine::Native, Engine::Sharded] {
        let server = serve(source(engine.clone(), 4, 4, 4, u64::MAX / 2));
        let remote = RemoteSource::connect(server.local_addr()).unwrap();
        let local = source(engine, 4, 4, 4, u64::MAX / 2);
        assert_eq!(remote.n_streams(), 16);
        assert_eq!(remote.n_groups(), 4);
        assert_eq!(remote.group_width(), 4);

        // The same mixed call sequence against both; every result must
        // agree (the local engines are oracle-pinned elsewhere).
        let mut a = vec![0u32; 7];
        let mut b = vec![0u32; 7];
        remote.fetch(5, &mut a).unwrap();
        local.fetch(5, &mut b).unwrap();
        assert_eq!(a, b, "fetch over the wire");

        assert_eq!(
            remote.fetch_block(0, 8).unwrap(),
            local.fetch_block(0, 8).unwrap(),
            "fetch_block over the wire"
        );
        assert_eq!(
            remote.fetch_many(4).unwrap(),
            local.fetch_many(4).unwrap(),
            "fetch_many over the wire"
        );

        // Identity metadata crosses through LEASE.
        assert_eq!(remote.spec(5), local.spec(5), "spec over the wire");
        assert!(remote.spec(99).is_none());

        // Direct oracle spot-check: stream 5 (group 1, lane 1) consumed
        // 7 then 4 numbers.
        let mut s = ThunderingStream::new(splitmix64(42 ^ 1), 5);
        let expect: Vec<u32> = (0..7).map(|_| s.next_u32()).collect();
        assert_eq!(a, expect, "scalar replay");
    }
}

#[test]
fn stream_handle_over_the_wire_matches_scalar_replay() {
    let server = serve(source(Engine::Sharded, 2, 4, 16, u64::MAX / 2));
    let remote: Arc<dyn StreamSource> =
        Arc::new(RemoteSource::connect(server.local_addr()).unwrap());
    let mut h = StreamHandle::new(remote, 6).unwrap().with_chunk(7);
    // Interleave all three handle views; the sequence must stay
    // seamless across the network boundary.
    let mut got = Vec::new();
    for _ in 0..5 {
        got.push(h.next_u32().unwrap());
    }
    let mut buf = vec![0u32; 13];
    h.fill(&mut buf).unwrap();
    got.extend_from_slice(&buf);
    got.extend(h.by_ref().take(6));
    got.push(Prng32::next_u32(&mut h)); // the Prng32 view

    let mut s = ThunderingStream::new(splitmix64(42 ^ 1), 6);
    let expect: Vec<u32> = (0..got.len()).map(|_| s.next_u32()).collect();
    assert_eq!(got, expect);
}

#[test]
fn typed_errors_cross_the_wire_including_retryable_backpressure() {
    // Tight lag window: 2-lane groups, window 8.
    let server = serve(source(Engine::Native, 1, 2, 4, 8));
    let remote = RemoteSource::connect(server.local_addr()).unwrap();

    // Validation errors (client-side fast fail mirrors the server).
    let mut buf = vec![0u32; 4];
    assert_eq!(
        remote.fetch(2, &mut buf).unwrap_err(),
        Error::UnknownStream { stream: 2, have: 2 }
    );
    assert_eq!(
        remote.fetch_block(1, 4).unwrap_err(),
        Error::GroupOutOfRange { group: 1, have: 1 }
    );

    // Backpressure: lane 0 drains the whole window, one more number is
    // a typed retryable rejection — and consumed nothing.
    let mut eight = vec![0u32; 8];
    remote.fetch(0, &mut eight).unwrap();
    let mut one = vec![0u32; 1];
    let err = remote.fetch(0, &mut one).unwrap_err();
    assert_eq!(err, Error::LagWindowExceeded { lead: 9, window: 8 });
    assert!(err.is_retryable(), "{err}");
    // Catch the slow lane up over the wire, then the retry continues
    // seamlessly at row 8.
    remote.fetch(1, &mut eight).unwrap();
    remote.fetch(0, &mut one).unwrap();
    let mut s = ThunderingStream::new(splitmix64(42), 0);
    let mut expect = 0;
    for _ in 0..9 {
        expect = s.next_u32();
    }
    assert_eq!(one[0], expect, "row 8 after the rejected fetch");
}

#[test]
fn chunked_fill_delivers_in_order_exactly_once() {
    let server = serve(source(Engine::Sharded, 2, 4, 4, u64::MAX / 2));
    let client = RemoteClient::connect(server.local_addr()).unwrap();
    assert_eq!(client.info().n_streams, 8);
    client.lease(ReqTarget::Group(1)).unwrap();

    // One FILL, 5 sub-requests of 4 rows: chunks must arrive as seq
    // 0..5 with `last` only on the final one, and their concatenation
    // must equal 20 contiguous oracle rows.
    let req = client.submit_fill(&Request::group(1).rows(4), 5).unwrap();
    let mut all = Vec::new();
    for expect_seq in 0..5u32 {
        let chunk = client.next_chunk(req).unwrap();
        assert_eq!(chunk.seq, expect_seq, "in-order delivery");
        assert_eq!(chunk.last, expect_seq == 4, "last flag placement");
        all.extend(chunk.result.unwrap());
    }
    assert_eq!(all, oracle_block(1, 4, 0, 20), "contiguous across chunks");
    client.bye().unwrap();
    server.wait_sessions_closed(1);
}

#[test]
fn bye_flushes_every_data_frame_before_the_ack() {
    // Raw protocol exchange: FILL then an immediate BYE — the server's
    // ordered flush must still deliver all three DATA frames, in seq
    // order, before BYE_ACK, and BYE_ACK must be the last frame.
    let server = serve(source(Engine::Sharded, 1, 4, 4, u64::MAX / 2));
    let mut sock = TcpStream::connect(server.local_addr()).unwrap();
    protocol::write_frame(&mut sock, &Frame::Hello { version: protocol::VERSION }).unwrap();
    assert!(matches!(
        protocol::read_frame(&mut sock).unwrap(),
        Some(Frame::Welcome { .. })
    ));
    protocol::write_frame(
        &mut sock,
        &Frame::Fill {
            req: 9,
            target: ReqTarget::Group(0),
            rows: 4,
            repeat: 3,
            deadline_ms: 0,
        },
    )
    .unwrap();
    protocol::write_frame(&mut sock, &Frame::Bye).unwrap();

    let mut all = Vec::new();
    for expect_seq in 0..3u32 {
        match protocol::read_frame(&mut sock).unwrap() {
            Some(Frame::Data { req, seq, last, values }) => {
                assert_eq!((req, seq, last), (9, expect_seq, expect_seq == 2));
                all.extend(values);
            }
            other => panic!("expected DATA seq {expect_seq}, got {other:?}"),
        }
    }
    assert_eq!(all, oracle_block(0, 4, 0, 12), "flushed chunks replay the oracle");
    assert!(matches!(protocol::read_frame(&mut sock).unwrap(), Some(Frame::ByeAck)));
    assert!(protocol::read_frame(&mut sock).unwrap().is_none(), "clean close after ack");
    server.wait_sessions_closed(1);
}

#[test]
fn malformed_frames_are_rejected_and_the_server_survives() {
    let server = serve(source(Engine::Native, 1, 4, 4, u64::MAX / 2));

    // A garbage length prefix (4 GiB frame) must be answered with a
    // typed protocol error, not an allocation or a crash. (Exactly the
    // 4 header bytes, so the server closes with its receive buffer
    // drained — a clean FIN, not an RST racing our reply read.)
    let mut sock = TcpStream::connect(server.local_addr()).unwrap();
    std::io::Write::write_all(&mut sock, &[0xff; 4]).unwrap();
    match protocol::read_frame(&mut sock).unwrap() {
        Some(Frame::Err { error: Error::Protocol(_), .. }) => {}
        other => panic!("expected a typed protocol ERR, got {other:?}"),
    }
    assert!(protocol::read_frame(&mut sock).unwrap().is_none(), "connection closed");

    // A short read (valid header, truncated payload, then half-close)
    // is rejected the same way.
    let mut sock = TcpStream::connect(server.local_addr()).unwrap();
    let mut hello = Vec::new();
    protocol::write_frame(&mut hello, &Frame::Hello { version: protocol::VERSION }).unwrap();
    std::io::Write::write_all(&mut sock, &hello[..hello.len() - 2]).unwrap();
    sock.shutdown(std::net::Shutdown::Write).unwrap();
    match protocol::read_frame(&mut sock).unwrap() {
        Some(Frame::Err { error: Error::Protocol(_), .. }) => {}
        other => panic!("expected a typed protocol ERR, got {other:?}"),
    }

    // A bogus frame mid-session (the client must never send WELCOME).
    let mut sock = TcpStream::connect(server.local_addr()).unwrap();
    protocol::write_frame(&mut sock, &Frame::Hello { version: protocol::VERSION }).unwrap();
    assert!(matches!(
        protocol::read_frame(&mut sock).unwrap(),
        Some(Frame::Welcome { .. })
    ));
    protocol::write_frame(&mut sock, &Frame::ByeAck).unwrap();
    match protocol::read_frame(&mut sock).unwrap() {
        Some(Frame::Err { error: Error::Protocol(_), .. }) => {}
        other => panic!("expected a typed protocol ERR, got {other:?}"),
    }

    // Three abusive connections later, a clean client still gets
    // bit-identical service.
    server.wait_sessions_closed(3);
    let remote = RemoteSource::connect(server.local_addr()).unwrap();
    assert_eq!(remote.fetch_block(0, 4).unwrap(), oracle_block(0, 4, 0, 4));
}

#[test]
fn loadgen_eight_connections_deliver_exactly_once() {
    let server = serve(source(Engine::Sharded, 8, 8, 16, u64::MAX / 2));
    let cfg = LoadgenConfig {
        addr: server.local_addr().to_string(),
        connections: 8,
        numbers_per_conn: 8 * 16 * 8, // 8 chunks of one tile each
        chunk_rows: 16,
        ..LoadgenConfig::default()
    };
    let report = loadgen::run(&cfg).unwrap();
    assert_eq!(report.connections, 8);
    assert_eq!(report.numbers, 8 * 8 * 16 * 8, "every number delivered exactly once");
    assert_eq!(report.chunks, 8 * 8);
    assert!(report.seconds > 0.0);
    // Every connection said BYE and was fully torn down.
    server.wait_sessions_closed(8);
    assert!(server.sessions_closed() >= 8);
}

/// One big fill (2²⁰ numbers, several ms of generation) that occupies
/// its group while a second request queues behind it — the window the
/// lifecycle tests race their cancels/deadlines into.
const BIG_ROWS: usize = 1 << 18; // × width 4 = 2^20 numbers

#[test]
fn cancel_over_the_wire_resolves_typed_and_preserves_stream_state() {
    // Fill A is large and claims the group; fill B queues behind it.
    // CANCEL(B) is processed by the server's reader thread (µs) while A
    // is still generating (ms), so B is almost surely still pending and
    // resolves as a typed Cancelled chunk. The assertions also hold if
    // B wins the race and executes: either way every chunk arrives, in
    // order, and the stream state is consistent with exactly the DATA
    // the client received — a cancelled fill consumes nothing.
    let server = serve(source(Engine::Native, 1, 4, 4, u64::MAX / 2));
    let client = RemoteClient::connect(server.local_addr()).unwrap();
    let a = client.submit_fill(&Request::group(0).rows(BIG_ROWS), 1).unwrap();
    let b = client.submit_fill(&Request::group(0).rows(4), 1).unwrap();
    client.cancel(b).unwrap();

    let chunk_a = client.next_chunk(a).unwrap();
    assert_eq!((chunk_a.seq, chunk_a.last), (0, true));
    assert_eq!(
        chunk_a.result.unwrap(),
        oracle_block(0, 4, 0, BIG_ROWS),
        "fill A delivers the group's origin rows"
    );
    let chunk_b = client.next_chunk(b).unwrap();
    let b_rows = match chunk_b.result {
        Err(Error::Cancelled) => 0,
        Ok(values) => {
            // Cancel lost the race: B executed and must be bit-exact.
            assert_eq!(values, oracle_block(0, 4, BIG_ROWS, 4));
            4
        }
        Err(e) => panic!("unexpected error for the cancelled fill: {e}"),
    };
    // The stream cursor sits exactly past the delivered rows: a fresh
    // fill continues seamlessly from there.
    let next = client.submit_fill(&Request::group(0).rows(4), 1).unwrap();
    assert_eq!(
        client.next_chunk(next).unwrap().result.unwrap(),
        oracle_block(0, 4, BIG_ROWS + b_rows, 4),
        "post-cancel fill continues exactly after the delivered rows"
    );
    client.bye().unwrap();
    server.wait_sessions_closed(1);
}

#[test]
fn cancelled_multi_chunk_fill_keeps_a_contiguous_prefix() {
    // A chunked fill cancelled mid-flight: every one of its `repeat`
    // chunks still arrives, in seq order, as a contiguous bit-exact
    // DATA prefix followed only by Cancelled chunks (the server's
    // atomic cancel sweep guarantees no DATA after the first Cancelled).
    let server = serve(source(Engine::Sharded, 1, 4, 4, u64::MAX / 2));
    let client = RemoteClient::connect(server.local_addr()).unwrap();
    let repeat = 32u32;
    let req = client.submit_fill(&Request::group(0).rows(4), repeat).unwrap();
    client.cancel(req).unwrap();
    let mut delivered_rows = 0usize;
    let mut cancelled = 0u32;
    for expect_seq in 0..repeat {
        let chunk = client.next_chunk(req).unwrap();
        assert_eq!(chunk.seq, expect_seq, "in-order even under cancellation");
        assert_eq!(chunk.last, expect_seq + 1 == repeat);
        match chunk.result {
            Ok(values) => {
                assert_eq!(cancelled, 0, "DATA after a Cancelled chunk");
                assert_eq!(
                    values,
                    oracle_block(0, 4, delivered_rows, 4),
                    "prefix chunk {expect_seq} bit-exact"
                );
                delivered_rows += 4;
            }
            Err(Error::Cancelled) => cancelled += 1,
            Err(e) => panic!("unexpected error at seq {expect_seq}: {e}"),
        }
    }
    // The cancelled tail consumed nothing: the next fill continues at
    // the prefix end.
    let next = client.submit_fill(&Request::group(0).rows(4), 1).unwrap();
    assert_eq!(
        client.next_chunk(next).unwrap().result.unwrap(),
        oracle_block(0, 4, delivered_rows, 4)
    );
    client.bye().unwrap();
    server.wait_sessions_closed(1);
}

#[test]
fn expired_fill_resolves_typed_and_consumes_nothing_over_the_wire() {
    // Fill A occupies the group for several ms; fill B carries a 1 ms
    // deadline and queues behind it, so B's deadline passes before an
    // executor can reach it — it resolves as a typed, retryable
    // DeadlineExceeded chunk and consumes no stream state. (Should B
    // ever win the race on a pathologically slow-clock host, the
    // alternate arm still verifies bit-exactness.)
    let server = serve(source(Engine::Native, 1, 4, 4, u64::MAX / 2));
    let client = RemoteClient::connect(server.local_addr()).unwrap();
    let a = client.submit_fill(&Request::group(0).rows(BIG_ROWS), 1).unwrap();
    let b = client
        .submit_fill(&Request::group(0).rows(4).deadline(Duration::from_millis(1)), 1)
        .unwrap();
    assert_eq!(
        client.next_chunk(a).unwrap().result.unwrap(),
        oracle_block(0, 4, 0, BIG_ROWS)
    );
    let b_rows = match client.next_chunk(b).unwrap().result {
        Err(e) => {
            assert_eq!(e, Error::DeadlineExceeded);
            assert!(e.is_retryable(), "expiry must be retryable over the wire");
            0
        }
        Ok(values) => {
            assert_eq!(values, oracle_block(0, 4, BIG_ROWS, 4));
            4
        }
    };
    // Retrying (the whole point of the retryable classification)
    // continues the sequence seamlessly.
    let retry = client.submit_fill(&Request::group(0).rows(4), 1).unwrap();
    assert_eq!(
        client.next_chunk(retry).unwrap().result.unwrap(),
        oracle_block(0, 4, BIG_ROWS + b_rows, 4)
    );
    client.bye().unwrap();
    server.wait_sessions_closed(1);
}

#[test]
fn remote_submit_mirrors_the_local_lifecycle_surface() {
    // RemoteSource::submit/wait/CancelHandle — the wire twin of
    // CompletionQueue::submit. A generous deadline delivers normally;
    // the cancel handle is cloneable and cancel-after-delivery is a
    // harmless no-op.
    let server = serve(source(Engine::Sharded, 2, 4, 4, u64::MAX / 2));
    let remote = RemoteSource::connect(server.local_addr()).unwrap();
    let (id, cancel) = remote
        .submit(Request::group(1).rows(8).deadline(Duration::from_secs(60)))
        .unwrap();
    let _clone = cancel.clone();
    assert_eq!(remote.wait(id).unwrap(), oracle_block(1, 4, 0, 8));
    cancel.cancel(); // best-effort, already delivered — must not break anything
    // Validation happens before anything touches the wire.
    assert!(matches!(
        remote.submit(Request::group(7).rows(1)).unwrap_err(),
        Error::GroupOutOfRange { group: 7, have: 2 }
    ));
    // The async pipeline is bounded: submissions past the cap fail
    // fast (typed) instead of wedging the connection against the
    // server's session window, and waiting frees the slots.
    let ids: Vec<u64> = (0..8)
        .map(|_| remote.submit(Request::group(0).rows(2)).unwrap().0)
        .collect();
    assert!(matches!(
        remote.submit(Request::group(0).rows(2)).unwrap_err(),
        Error::InvalidConfig(_)
    ));
    let mut drained = 0usize;
    for id in ids {
        drained += remote.wait(id).unwrap().len();
    }
    assert_eq!(drained, 8 * 2 * 4, "all bounded submissions delivered");
    remote.submit(Request::group(0).rows(2)).unwrap();
    // The connection stays healthy for the synchronous surface.
    assert_eq!(remote.fetch_block(1, 4).unwrap(), oracle_block(1, 4, 8, 4));
}

#[test]
fn default_deadline_arms_the_synchronous_surface() {
    // A RemoteSource with a generous default deadline serves the
    // drop-in surface unchanged (the deadline rides every FILL).
    let server = serve(source(Engine::Native, 2, 4, 4, u64::MAX / 2));
    let remote = RemoteSource::connect(server.local_addr())
        .unwrap()
        .with_default_deadline(Duration::from_secs(60));
    let mut buf = vec![0u32; 7];
    remote.fetch(5, &mut buf).unwrap();
    let mut s = ThunderingStream::new(splitmix64(42 ^ 1), 5);
    let expect: Vec<u32> = (0..7).map(|_| s.next_u32()).collect();
    assert_eq!(buf, expect);
    assert_eq!(remote.fetch_block(0, 4).unwrap(), oracle_block(0, 4, 0, 4));
}

#[test]
fn loadgen_cancel_storm_and_deadline_survive_cleanly() {
    // The CI cancel-storm shape in-process: every second fill of every
    // connection is cancelled right after submission, all fills carry a
    // generous deadline. Delivery invariants (seq order, contiguous
    // prefixes) are verified inside the driver; here we check the
    // accounting adds up and every session tears down cleanly.
    let server = serve(source(Engine::Sharded, 4, 8, 16, u64::MAX / 2));
    let cfg = LoadgenConfig {
        addr: server.local_addr().to_string(),
        connections: 4,
        numbers_per_conn: 8 * 16 * 8,
        chunk_rows: 16,
        fills_per_conn: 4,
        deadline_ms: 60_000,
        cancel_storm: true,
        ..LoadgenConfig::default()
    };
    let report = loadgen::run(&cfg).unwrap();
    assert_eq!(report.connections, 4);
    // Every chunk resolved exactly once, one way or another.
    assert_eq!(
        report.chunks + report.cancelled_chunks + report.expired_chunks,
        4 * 4 * 2, // connections × fills × chunks-per-fill
        "chunk accounting: {report:?}"
    );
    assert_eq!(report.numbers, report.chunks * 8 * 16, "delivered chunks are full-size");
    assert!(
        !report.fill_latencies_s.is_empty(),
        "uncancelled fills produce latency samples"
    );
    server.wait_sessions_closed(4);
}

#[test]
fn oversized_fetches_fail_typed_before_touching_the_wire() {
    let server = serve(source(Engine::Native, 1, 4, 4, u64::MAX / 2));
    let remote = RemoteSource::connect(server.local_addr()).unwrap();
    let max = remote.info().max_fill;
    let mut big = vec![0u32; max as usize + 1];
    assert!(matches!(
        remote.fetch(0, &mut big).unwrap_err(),
        Error::InvalidConfig(_)
    ));
    // The connection is still healthy afterwards.
    assert_eq!(remote.fetch_block(0, 4).unwrap(), oracle_block(0, 4, 0, 4));
}
