//! Serving-layer loopback integration: determinism over the wire (the
//! bytes a client reads are exactly the scalar replay, on both
//! engines), typed errors across the boundary, chunked-fill
//! exactly-once ordering, the BYE flush contract, malformed-frame
//! rejection, and the multi-connection loadgen driver.

use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use thundering::dist::shape_words;
use thundering::prng::{splitmix64, Prng32, ThunderingBatch, ThunderingStream};
use thundering::DistSpec;
use thundering::serve::loadgen::{self, LoadgenConfig};
use thundering::serve::protocol::{self, Frame};
use thundering::serve::{RemoteClient, RemoteSource, ServeConfig, Server};
use thundering::{
    Engine, EngineBuilder, Error, ReqTarget, Request, StreamHandle, StreamSource,
};

/// A source with the test shape: `groups × width` streams, seed 42.
fn source(
    engine: Engine,
    groups: usize,
    width: usize,
    rows_per_tile: usize,
    lag_window: u64,
) -> Arc<dyn StreamSource> {
    EngineBuilder::new((groups * width) as u64)
        .engine(engine)
        .group_width(width)
        .rows_per_tile(rows_per_tile)
        .lag_window(lag_window)
        .root_seed(42)
        .build_arc()
        .unwrap()
}

fn serve(src: Arc<dyn StreamSource>) -> Server {
    Server::start(src, "127.0.0.1:0", ServeConfig::default()).unwrap()
}

/// Scalar oracle: rows `skip..skip+rows` of one group's block.
fn oracle_block(group: u64, width: usize, skip: usize, rows: usize) -> Vec<u32> {
    let mut batch = ThunderingBatch::new(splitmix64(42 ^ group), width, group * width as u64);
    if skip > 0 {
        batch.tile(skip);
    }
    batch.tile(rows)
}

/// Shaped oracle (DESIGN.md §7): shaped rows `skip..skip+rows` of one
/// group, i.e. the raw oracle rows scaled by the spec's fixed
/// draws-per-row and shaped lane-by-lane.
fn shaped_oracle(
    spec: DistSpec,
    group: u64,
    width: usize,
    skip: usize,
    rows: usize,
) -> Vec<u32> {
    let k = spec.draws_per_row();
    shape_words(spec, &oracle_block(group, width, skip * k, rows * k), width)
}

#[test]
fn remote_source_is_bit_identical_to_local_on_both_engines() {
    for engine in [Engine::Native, Engine::Sharded] {
        let server = serve(source(engine.clone(), 4, 4, 4, u64::MAX / 2));
        let remote = RemoteSource::connect(server.local_addr()).unwrap();
        let local = source(engine, 4, 4, 4, u64::MAX / 2);
        assert_eq!(remote.n_streams(), 16);
        assert_eq!(remote.n_groups(), 4);
        assert_eq!(remote.group_width(), 4);

        // The same mixed call sequence against both; every result must
        // agree (the local engines are oracle-pinned elsewhere).
        let mut a = vec![0u32; 7];
        let mut b = vec![0u32; 7];
        remote.fetch(5, &mut a).unwrap();
        local.fetch(5, &mut b).unwrap();
        assert_eq!(a, b, "fetch over the wire");

        assert_eq!(
            remote.fetch_block(0, 8).unwrap(),
            local.fetch_block(0, 8).unwrap(),
            "fetch_block over the wire"
        );
        assert_eq!(
            remote.fetch_many(4).unwrap(),
            local.fetch_many(4).unwrap(),
            "fetch_many over the wire"
        );

        // Identity metadata crosses through LEASE.
        assert_eq!(remote.spec(5), local.spec(5), "spec over the wire");
        assert!(remote.spec(99).is_none());

        // Direct oracle spot-check: stream 5 (group 1, lane 1) consumed
        // 7 then 4 numbers.
        let mut s = ThunderingStream::new(splitmix64(42 ^ 1), 5);
        let expect: Vec<u32> = (0..7).map(|_| s.next_u32()).collect();
        assert_eq!(a, expect, "scalar replay");
    }
}

#[test]
fn stream_handle_over_the_wire_matches_scalar_replay() {
    let server = serve(source(Engine::Sharded, 2, 4, 16, u64::MAX / 2));
    let remote: Arc<dyn StreamSource> =
        Arc::new(RemoteSource::connect(server.local_addr()).unwrap());
    let mut h = StreamHandle::new(remote, 6).unwrap().with_chunk(7);
    // Interleave all three handle views; the sequence must stay
    // seamless across the network boundary.
    let mut got = Vec::new();
    for _ in 0..5 {
        got.push(h.next_u32().unwrap());
    }
    let mut buf = vec![0u32; 13];
    h.fill(&mut buf).unwrap();
    got.extend_from_slice(&buf);
    got.extend(h.by_ref().take(6));
    got.push(Prng32::next_u32(&mut h)); // the Prng32 view

    let mut s = ThunderingStream::new(splitmix64(42 ^ 1), 6);
    let expect: Vec<u32> = (0..got.len()).map(|_| s.next_u32()).collect();
    assert_eq!(got, expect);
}

#[test]
fn typed_errors_cross_the_wire_including_retryable_backpressure() {
    // Tight lag window: 2-lane groups, window 8.
    let server = serve(source(Engine::Native, 1, 2, 4, 8));
    let remote = RemoteSource::connect(server.local_addr()).unwrap();

    // Validation errors (client-side fast fail mirrors the server).
    let mut buf = vec![0u32; 4];
    assert_eq!(
        remote.fetch(2, &mut buf).unwrap_err(),
        Error::UnknownStream { stream: 2, have: 2 }
    );
    assert_eq!(
        remote.fetch_block(1, 4).unwrap_err(),
        Error::GroupOutOfRange { group: 1, have: 1 }
    );

    // Backpressure: lane 0 drains the whole window, one more number is
    // a typed retryable rejection — and consumed nothing.
    let mut eight = vec![0u32; 8];
    remote.fetch(0, &mut eight).unwrap();
    let mut one = vec![0u32; 1];
    let err = remote.fetch(0, &mut one).unwrap_err();
    assert_eq!(err, Error::LagWindowExceeded { lead: 9, window: 8 });
    assert!(err.is_retryable(), "{err}");
    // Catch the slow lane up over the wire, then the retry continues
    // seamlessly at row 8.
    remote.fetch(1, &mut eight).unwrap();
    remote.fetch(0, &mut one).unwrap();
    let mut s = ThunderingStream::new(splitmix64(42), 0);
    let mut expect = 0;
    for _ in 0..9 {
        expect = s.next_u32();
    }
    assert_eq!(one[0], expect, "row 8 after the rejected fetch");
}

#[test]
fn chunked_fill_delivers_in_order_exactly_once() {
    let server = serve(source(Engine::Sharded, 2, 4, 4, u64::MAX / 2));
    let client = RemoteClient::connect(server.local_addr()).unwrap();
    assert_eq!(client.info().n_streams, 8);
    client.lease(ReqTarget::Group(1)).unwrap();

    // One FILL, 5 sub-requests of 4 rows: chunks must arrive as seq
    // 0..5 with `last` only on the final one, and their concatenation
    // must equal 20 contiguous oracle rows.
    let req = client.submit_fill(&Request::group(1).rows(4), 5).unwrap();
    let mut all = Vec::new();
    for expect_seq in 0..5u32 {
        let chunk = client.next_chunk(req).unwrap();
        assert_eq!(chunk.seq, expect_seq, "in-order delivery");
        assert_eq!(chunk.last, expect_seq == 4, "last flag placement");
        all.extend(chunk.result.unwrap());
    }
    assert_eq!(all, oracle_block(1, 4, 0, 20), "contiguous across chunks");
    client.bye().unwrap();
    server.wait_sessions_closed(1);
}

#[test]
fn bye_flushes_every_data_frame_before_the_ack() {
    // Raw protocol exchange: FILL then an immediate BYE — the server's
    // ordered flush must still deliver all three DATA frames, in seq
    // order, before BYE_ACK, and BYE_ACK must be the last frame.
    let server = serve(source(Engine::Sharded, 1, 4, 4, u64::MAX / 2));
    let mut sock = TcpStream::connect(server.local_addr()).unwrap();
    protocol::write_frame(&mut sock, &Frame::Hello { version: protocol::VERSION }).unwrap();
    assert!(matches!(
        protocol::read_frame(&mut sock).unwrap(),
        Some(Frame::Welcome { .. })
    ));
    protocol::write_frame(
        &mut sock,
        &Frame::Fill {
            req: 9,
            target: ReqTarget::Group(0),
            rows: 4,
            repeat: 3,
            deadline_ms: 0,
            tag: 0,
            dist: None,
        },
    )
    .unwrap();
    protocol::write_frame(&mut sock, &Frame::Bye).unwrap();

    let mut all = Vec::new();
    for expect_seq in 0..3u32 {
        match protocol::read_frame(&mut sock).unwrap() {
            Some(Frame::Data { req, seq, last, values }) => {
                assert_eq!((req, seq, last), (9, expect_seq, expect_seq == 2));
                all.extend(values);
            }
            other => panic!("expected DATA seq {expect_seq}, got {other:?}"),
        }
    }
    assert_eq!(all, oracle_block(0, 4, 0, 12), "flushed chunks replay the oracle");
    assert!(matches!(protocol::read_frame(&mut sock).unwrap(), Some(Frame::ByeAck)));
    assert!(protocol::read_frame(&mut sock).unwrap().is_none(), "clean close after ack");
    server.wait_sessions_closed(1);
}

#[test]
fn malformed_frames_are_rejected_and_the_server_survives() {
    let server = serve(source(Engine::Native, 1, 4, 4, u64::MAX / 2));

    // A garbage length prefix (4 GiB frame) must be answered with a
    // typed protocol error, not an allocation or a crash. (Exactly the
    // 4 header bytes, so the server closes with its receive buffer
    // drained — a clean FIN, not an RST racing our reply read.)
    let mut sock = TcpStream::connect(server.local_addr()).unwrap();
    std::io::Write::write_all(&mut sock, &[0xff; 4]).unwrap();
    match protocol::read_frame(&mut sock).unwrap() {
        Some(Frame::Err { error: Error::Protocol(_), .. }) => {}
        other => panic!("expected a typed protocol ERR, got {other:?}"),
    }
    assert!(protocol::read_frame(&mut sock).unwrap().is_none(), "connection closed");

    // A short read (valid header, truncated payload, then half-close)
    // is rejected the same way.
    let mut sock = TcpStream::connect(server.local_addr()).unwrap();
    let mut hello = Vec::new();
    protocol::write_frame(&mut hello, &Frame::Hello { version: protocol::VERSION }).unwrap();
    std::io::Write::write_all(&mut sock, &hello[..hello.len() - 2]).unwrap();
    sock.shutdown(std::net::Shutdown::Write).unwrap();
    match protocol::read_frame(&mut sock).unwrap() {
        Some(Frame::Err { error: Error::Protocol(_), .. }) => {}
        other => panic!("expected a typed protocol ERR, got {other:?}"),
    }

    // A bogus frame mid-session (the client must never send WELCOME).
    let mut sock = TcpStream::connect(server.local_addr()).unwrap();
    protocol::write_frame(&mut sock, &Frame::Hello { version: protocol::VERSION }).unwrap();
    assert!(matches!(
        protocol::read_frame(&mut sock).unwrap(),
        Some(Frame::Welcome { .. })
    ));
    protocol::write_frame(&mut sock, &Frame::ByeAck).unwrap();
    match protocol::read_frame(&mut sock).unwrap() {
        Some(Frame::Err { error: Error::Protocol(_), .. }) => {}
        other => panic!("expected a typed protocol ERR, got {other:?}"),
    }

    // Three abusive connections later, a clean client still gets
    // bit-identical service.
    server.wait_sessions_closed(3);
    let remote = RemoteSource::connect(server.local_addr()).unwrap();
    assert_eq!(remote.fetch_block(0, 4).unwrap(), oracle_block(0, 4, 0, 4));
}

#[test]
fn loadgen_eight_connections_deliver_exactly_once() {
    let server = serve(source(Engine::Sharded, 8, 8, 16, u64::MAX / 2));
    let cfg = LoadgenConfig {
        addr: server.local_addr().to_string(),
        connections: 8,
        numbers_per_conn: 8 * 16 * 8, // 8 chunks of one tile each
        chunk_rows: 16,
        ..LoadgenConfig::default()
    };
    let report = loadgen::run(&cfg).unwrap();
    assert_eq!(report.connections, 8);
    assert_eq!(report.numbers, 8 * 8 * 16 * 8, "every number delivered exactly once");
    assert_eq!(report.chunks, 8 * 8);
    assert!(report.seconds > 0.0);
    // Every connection said BYE and was fully torn down.
    server.wait_sessions_closed(8);
    assert!(server.sessions_closed() >= 8);
}

/// One big fill (2²⁰ numbers, several ms of generation) that occupies
/// its group while a second request queues behind it — the window the
/// lifecycle tests race their cancels/deadlines into.
const BIG_ROWS: usize = 1 << 18; // × width 4 = 2^20 numbers

#[test]
fn cancel_over_the_wire_resolves_typed_and_preserves_stream_state() {
    // Fill A is large and claims the group; fill B queues behind it.
    // CANCEL(B) is processed by the server's reader thread (µs) while A
    // is still generating (ms), so B is almost surely still pending and
    // resolves as a typed Cancelled chunk. The assertions also hold if
    // B wins the race and executes: either way every chunk arrives, in
    // order, and the stream state is consistent with exactly the DATA
    // the client received — a cancelled fill consumes nothing.
    let server = serve(source(Engine::Native, 1, 4, 4, u64::MAX / 2));
    let client = RemoteClient::connect(server.local_addr()).unwrap();
    let a = client.submit_fill(&Request::group(0).rows(BIG_ROWS), 1).unwrap();
    let b = client.submit_fill(&Request::group(0).rows(4), 1).unwrap();
    client.cancel(b).unwrap();

    let chunk_a = client.next_chunk(a).unwrap();
    assert_eq!((chunk_a.seq, chunk_a.last), (0, true));
    assert_eq!(
        chunk_a.result.unwrap(),
        oracle_block(0, 4, 0, BIG_ROWS),
        "fill A delivers the group's origin rows"
    );
    let chunk_b = client.next_chunk(b).unwrap();
    let b_rows = match chunk_b.result {
        Err(Error::Cancelled) => 0,
        Ok(values) => {
            // Cancel lost the race: B executed and must be bit-exact.
            assert_eq!(values, oracle_block(0, 4, BIG_ROWS, 4));
            4
        }
        Err(e) => panic!("unexpected error for the cancelled fill: {e}"),
    };
    // The stream cursor sits exactly past the delivered rows: a fresh
    // fill continues seamlessly from there.
    let next = client.submit_fill(&Request::group(0).rows(4), 1).unwrap();
    assert_eq!(
        client.next_chunk(next).unwrap().result.unwrap(),
        oracle_block(0, 4, BIG_ROWS + b_rows, 4),
        "post-cancel fill continues exactly after the delivered rows"
    );
    client.bye().unwrap();
    server.wait_sessions_closed(1);
}

#[test]
fn cancelled_multi_chunk_fill_keeps_a_contiguous_prefix() {
    // A chunked fill cancelled mid-flight: every one of its `repeat`
    // chunks still arrives, in seq order, as a contiguous bit-exact
    // DATA prefix followed only by Cancelled chunks (the server's
    // atomic cancel sweep guarantees no DATA after the first Cancelled).
    let server = serve(source(Engine::Sharded, 1, 4, 4, u64::MAX / 2));
    let client = RemoteClient::connect(server.local_addr()).unwrap();
    let repeat = 32u32;
    let req = client.submit_fill(&Request::group(0).rows(4), repeat).unwrap();
    client.cancel(req).unwrap();
    let mut delivered_rows = 0usize;
    let mut cancelled = 0u32;
    for expect_seq in 0..repeat {
        let chunk = client.next_chunk(req).unwrap();
        assert_eq!(chunk.seq, expect_seq, "in-order even under cancellation");
        assert_eq!(chunk.last, expect_seq + 1 == repeat);
        match chunk.result {
            Ok(values) => {
                assert_eq!(cancelled, 0, "DATA after a Cancelled chunk");
                assert_eq!(
                    values,
                    oracle_block(0, 4, delivered_rows, 4),
                    "prefix chunk {expect_seq} bit-exact"
                );
                delivered_rows += 4;
            }
            Err(Error::Cancelled) => cancelled += 1,
            Err(e) => panic!("unexpected error at seq {expect_seq}: {e}"),
        }
    }
    // The cancelled tail consumed nothing: the next fill continues at
    // the prefix end.
    let next = client.submit_fill(&Request::group(0).rows(4), 1).unwrap();
    assert_eq!(
        client.next_chunk(next).unwrap().result.unwrap(),
        oracle_block(0, 4, delivered_rows, 4)
    );
    client.bye().unwrap();
    server.wait_sessions_closed(1);
}

#[test]
fn expired_fill_resolves_typed_and_consumes_nothing_over_the_wire() {
    // Fill A occupies the group for several ms; fill B carries a 1 ms
    // deadline and queues behind it, so B's deadline passes before an
    // executor can reach it — it resolves as a typed, retryable
    // DeadlineExceeded chunk and consumes no stream state. (Should B
    // ever win the race on a pathologically slow-clock host, the
    // alternate arm still verifies bit-exactness.)
    let server = serve(source(Engine::Native, 1, 4, 4, u64::MAX / 2));
    let client = RemoteClient::connect(server.local_addr()).unwrap();
    let a = client.submit_fill(&Request::group(0).rows(BIG_ROWS), 1).unwrap();
    let b = client
        .submit_fill(&Request::group(0).rows(4).deadline(Duration::from_millis(1)), 1)
        .unwrap();
    assert_eq!(
        client.next_chunk(a).unwrap().result.unwrap(),
        oracle_block(0, 4, 0, BIG_ROWS)
    );
    let b_rows = match client.next_chunk(b).unwrap().result {
        Err(e) => {
            assert_eq!(e, Error::DeadlineExceeded);
            assert!(e.is_retryable(), "expiry must be retryable over the wire");
            0
        }
        Ok(values) => {
            assert_eq!(values, oracle_block(0, 4, BIG_ROWS, 4));
            4
        }
    };
    // Retrying (the whole point of the retryable classification)
    // continues the sequence seamlessly.
    let retry = client.submit_fill(&Request::group(0).rows(4), 1).unwrap();
    assert_eq!(
        client.next_chunk(retry).unwrap().result.unwrap(),
        oracle_block(0, 4, BIG_ROWS + b_rows, 4)
    );
    client.bye().unwrap();
    server.wait_sessions_closed(1);
}

#[test]
fn remote_submit_mirrors_the_local_lifecycle_surface() {
    // RemoteSource::submit/wait/CancelHandle — the wire twin of
    // CompletionQueue::submit. A generous deadline delivers normally;
    // the cancel handle is cloneable and cancel-after-delivery is a
    // harmless no-op.
    let server = serve(source(Engine::Sharded, 2, 4, 4, u64::MAX / 2));
    let remote = RemoteSource::connect(server.local_addr()).unwrap();
    let (id, cancel) = remote
        .submit(Request::group(1).rows(8).deadline(Duration::from_secs(60)))
        .unwrap();
    let _clone = cancel.clone();
    assert_eq!(remote.wait(id).unwrap(), oracle_block(1, 4, 0, 8));
    cancel.cancel(); // best-effort, already delivered — must not break anything
    // Validation happens before anything touches the wire.
    assert!(matches!(
        remote.submit(Request::group(7).rows(1)).unwrap_err(),
        Error::GroupOutOfRange { group: 7, have: 2 }
    ));
    // The async pipeline is bounded: submissions past the cap fail
    // fast (typed) instead of wedging the connection against the
    // server's session window, and waiting frees the slots.
    let ids: Vec<u64> = (0..8)
        .map(|_| remote.submit(Request::group(0).rows(2)).unwrap().0)
        .collect();
    assert!(matches!(
        remote.submit(Request::group(0).rows(2)).unwrap_err(),
        Error::InvalidConfig(_)
    ));
    let mut drained = 0usize;
    for id in ids {
        drained += remote.wait(id).unwrap().len();
    }
    assert_eq!(drained, 8 * 2 * 4, "all bounded submissions delivered");
    remote.submit(Request::group(0).rows(2)).unwrap();
    // The connection stays healthy for the synchronous surface.
    assert_eq!(remote.fetch_block(1, 4).unwrap(), oracle_block(1, 4, 8, 4));
}

#[test]
fn default_deadline_arms_the_synchronous_surface() {
    // A RemoteSource with a generous default deadline serves the
    // drop-in surface unchanged (the deadline rides every FILL).
    let server = serve(source(Engine::Native, 2, 4, 4, u64::MAX / 2));
    let remote = RemoteSource::connect(server.local_addr())
        .unwrap()
        .with_default_deadline(Duration::from_secs(60));
    let mut buf = vec![0u32; 7];
    remote.fetch(5, &mut buf).unwrap();
    let mut s = ThunderingStream::new(splitmix64(42 ^ 1), 5);
    let expect: Vec<u32> = (0..7).map(|_| s.next_u32()).collect();
    assert_eq!(buf, expect);
    assert_eq!(remote.fetch_block(0, 4).unwrap(), oracle_block(0, 4, 0, 4));
}

#[test]
fn loadgen_cancel_storm_and_deadline_survive_cleanly() {
    // The CI cancel-storm shape in-process: every second fill of every
    // connection is cancelled right after submission, all fills carry a
    // generous deadline. Delivery invariants (seq order, contiguous
    // prefixes) are verified inside the driver; here we check the
    // accounting adds up and every session tears down cleanly.
    let server = serve(source(Engine::Sharded, 4, 8, 16, u64::MAX / 2));
    let cfg = LoadgenConfig {
        addr: server.local_addr().to_string(),
        connections: 4,
        numbers_per_conn: 8 * 16 * 8,
        chunk_rows: 16,
        fills_per_conn: 4,
        deadline_ms: 60_000,
        cancel_storm: true,
        ..LoadgenConfig::default()
    };
    let report = loadgen::run(&cfg).unwrap();
    assert_eq!(report.connections, 4);
    // Every chunk resolved exactly once, one way or another.
    assert_eq!(
        report.chunks + report.cancelled_chunks + report.expired_chunks,
        4 * 4 * 2, // connections × fills × chunks-per-fill
        "chunk accounting: {report:?}"
    );
    assert_eq!(report.numbers, report.chunks * 8 * 16, "delivered chunks are full-size");
    assert!(
        !report.fill_latencies_s.is_empty(),
        "uncancelled fills produce latency samples"
    );
    server.wait_sessions_closed(4);
}

#[test]
fn quota_rejection_is_typed_retryable_and_consumes_nothing() {
    // Per-tenant admission control: a FILL that would push its tag past
    // the in-flight quota is rejected whole — one typed, retryable ERR,
    // no stream state consumed, no quota reserved.
    let server = Server::start(
        source(Engine::Native, 1, 4, 4, u64::MAX / 2),
        "127.0.0.1:0",
        ServeConfig { quota: 8, ..ServeConfig::default() },
    )
    .unwrap();
    let client = RemoteClient::connect(server.local_addr()).unwrap();
    let rejected = client.submit_fill(&Request::group(0).rows(4), 9).unwrap();
    let chunk = client.next_chunk(rejected).unwrap();
    assert_eq!((chunk.seq, chunk.last), (0, true), "rejected whole, one reply");
    let err = chunk.result.unwrap_err();
    assert_eq!(err, Error::QuotaExceeded { in_flight: 0, quota: 8 });
    assert!(err.is_retryable(), "{err}");
    // The rejection consumed nothing: an in-quota fill starts at row 0
    // and is bit-exact.
    let ok = client.submit_fill(&Request::group(0).rows(4), 8).unwrap();
    let mut all = Vec::new();
    for expect_seq in 0..8u32 {
        let chunk = client.next_chunk(ok).unwrap();
        assert_eq!(chunk.seq, expect_seq);
        all.extend(chunk.result.unwrap());
    }
    assert_eq!(all, oracle_block(0, 4, 0, 32), "post-rejection fill starts at row 0");
    client.bye().unwrap();
    server.wait_sessions_closed(1);
}

#[test]
fn qos_tags_flow_end_to_end_through_the_weighted_scheduler() {
    // Two tenants with configured drain weights, concurrently, on
    // distinct groups: the tag rides every FILL frame, both classes
    // drain through the weighted-fair scheduler, and each tenant's
    // bytes stay bit-exact. (The fairness ratio itself is pinned by the
    // scheduler's unit tests; this is the wire-to-engine plumbing.)
    let server = Server::start(
        source(Engine::Sharded, 2, 4, 4, u64::MAX / 2),
        "127.0.0.1:0",
        ServeConfig { qos_weights: vec![(1, 4), (2, 1)], ..ServeConfig::default() },
    )
    .unwrap();
    let addr = server.local_addr();
    std::thread::scope(|s| {
        for (tag, group) in [(1u64, 0usize), (2, 1)] {
            s.spawn(move || {
                let client = RemoteClient::connect(addr).unwrap();
                let req = client
                    .submit_fill(&Request::group(group).rows(4).tag(tag), 8)
                    .unwrap();
                let mut all = Vec::new();
                for expect_seq in 0..8u32 {
                    let chunk = client.next_chunk(req).unwrap();
                    assert_eq!(chunk.seq, expect_seq, "tenant {tag} in order");
                    all.extend(chunk.result.unwrap());
                }
                assert_eq!(
                    all,
                    oracle_block(group as u64, 4, 0, 32),
                    "tenant {tag} bit-exact under fair drain"
                );
                client.bye().unwrap();
            });
        }
    });
    server.wait_sessions_closed(2);
}

#[test]
fn lease_resumption_replays_lost_rows_bit_identically() {
    // Connection 1 tracks group 0, draws 8 rows, and dies without a
    // goodbye. Connection 2 resumes from cursor 0: the dead
    // connection's rows replay out of the retention ring, stitched
    // seamlessly into fresh generation.
    let server = serve(source(Engine::Native, 1, 4, 4, u64::MAX / 2));
    let conn1 = RemoteClient::connect(server.local_addr()).unwrap();
    assert_eq!(conn1.lease_resume(ReqTarget::Group(0), 0).unwrap(), 0, "fresh track");
    let first = conn1.fill(&Request::group(0).rows(8)).unwrap();
    assert_eq!(first, oracle_block(0, 4, 0, 8));
    drop(conn1); // dies mid-lease, no BYE
    server.wait_sessions_closed(1);

    let conn2 = RemoteClient::connect(server.local_addr()).unwrap();
    assert_eq!(
        conn2.lease_resume(ReqTarget::Group(0), 0).unwrap(),
        8,
        "server cursor counts every generated row"
    );
    // 12 rows against an 8-row replay gap: the replay fronts the chunk
    // and the engine generates only the remainder — one full-size,
    // bit-exact chunk covering rows 0..12.
    assert_eq!(
        conn2.fill(&Request::group(0).rows(12)).unwrap(),
        oracle_block(0, 4, 0, 12),
        "replay prefix + fresh remainder stitch into one chunk"
    );
    assert_eq!(
        conn2.fill(&Request::group(0).rows(4)).unwrap(),
        oracle_block(0, 4, 12, 4),
        "fresh generation continues past the stitched fill"
    );
    // A cursor ahead of the server is a client bug, typed.
    match conn2.lease_resume(ReqTarget::Group(0), 999) {
        Err(Error::InvalidConfig(m)) => assert!(m.contains("ahead"), "{m}"),
        other => panic!("expected a typed cursor rejection, got {other:?}"),
    }
    conn2.bye().unwrap();
    server.wait_sessions_closed(2);
}

#[test]
fn resumption_client_survives_a_dropped_connection_bit_identically() {
    use std::io::{Read, Write};
    use std::sync::mpsc;

    // The client dials a tiny in-test TCP proxy, so an ordered kill
    // looks exactly like a lost network path — and the reconnect dials
    // the proxy again, reaching a fresh server session.
    let server = serve(source(Engine::Native, 1, 4, 4, u64::MAX / 2));
    let upstream = server.local_addr();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let proxy_addr = listener.local_addr().unwrap();
    let (kill_tx, kill_rx) = mpsc::channel::<()>();
    std::thread::spawn(move || {
        for inbound in listener.incoming() {
            let Ok(client_side) = inbound else { break };
            let Ok(server_side) = TcpStream::connect(upstream) else { break };
            let kill_c = client_side.try_clone().unwrap();
            let kill_s = server_side.try_clone().unwrap();
            let back = (server_side.try_clone().unwrap(), client_side.try_clone().unwrap());
            let pump = |mut from: TcpStream, mut to: TcpStream| {
                move || {
                    let mut buf = [0u8; 4096];
                    loop {
                        match from.read(&mut buf) {
                            Ok(0) | Err(_) => break,
                            Ok(n) => {
                                if to.write_all(&buf[..n]).is_err() {
                                    break;
                                }
                            }
                        }
                    }
                    let _ = to.shutdown(std::net::Shutdown::Both);
                }
            };
            std::thread::spawn(pump(client_side, server_side));
            std::thread::spawn(pump(back.0, back.1));
            match kill_rx.recv() {
                Ok(()) => {
                    let _ = kill_c.shutdown(std::net::Shutdown::Both);
                    let _ = kill_s.shutdown(std::net::Shutdown::Both);
                }
                Err(_) => break, // test over; leave the last connection be
            }
        }
    });

    let remote = RemoteSource::connect(proxy_addr)
        .unwrap()
        .with_resumption(10, Duration::from_millis(20));
    let first = remote.fetch_block(0, 8).unwrap();
    assert_eq!(first, oracle_block(0, 4, 0, 8));

    kill_tx.send(()).unwrap();
    std::thread::sleep(Duration::from_millis(100)); // let the kill land
    // The next fetch rides the reconnect: re-LEASE at the confirmed
    // cursor, then continue exactly where the dead connection stopped.
    assert_eq!(
        remote.fetch_block(0, 8).unwrap(),
        oracle_block(0, 4, 8, 8),
        "bit-identical continuation across the dropped connection"
    );
    assert_eq!(remote.fetch_block(0, 4).unwrap(), oracle_block(0, 4, 16, 4));
    drop(remote);
    server.wait_sessions_closed(2);
}

#[test]
fn reserved_request_id_is_rejected_over_the_wire() {
    // CONNECTION_REQ (u64::MAX) is the server's connection-level error
    // sentinel: a client FILL carrying it must die at frame decode with
    // a typed Protocol ERR — before it can corrupt reply routing.
    let server = serve(source(Engine::Native, 1, 4, 4, u64::MAX / 2));
    let mut sock = TcpStream::connect(server.local_addr()).unwrap();
    protocol::write_frame(&mut sock, &Frame::Hello { version: protocol::VERSION }).unwrap();
    assert!(matches!(
        protocol::read_frame(&mut sock).unwrap(),
        Some(Frame::Welcome { .. })
    ));
    protocol::write_frame(
        &mut sock,
        &Frame::Fill {
            req: protocol::CONNECTION_REQ,
            target: ReqTarget::Group(0),
            rows: 1,
            repeat: 1,
            deadline_ms: 0,
            tag: 0,
            dist: None,
        },
    )
    .unwrap();
    match protocol::read_frame(&mut sock).unwrap() {
        Some(Frame::Err { req, error: Error::Protocol(m), .. }) => {
            assert_eq!(req, protocol::CONNECTION_REQ);
            assert!(m.contains("reserved"), "{m}");
        }
        other => panic!("expected a typed protocol ERR, got {other:?}"),
    }
    assert!(protocol::read_frame(&mut sock).unwrap().is_none(), "connection closed");
    server.wait_sessions_closed(1);
    // The server survives to serve a clean client bit-identically.
    let remote = RemoteSource::connect(server.local_addr()).unwrap();
    assert_eq!(remote.fetch_block(0, 4).unwrap(), oracle_block(0, 4, 0, 4));
}

#[test]
fn loadgen_connect_failure_is_bounded_and_typed() {
    // A dead endpoint: the retry schedule is bounded (attempts ×
    // backoff) and the final failure is a typed error naming it — not
    // an unbounded sleep loop.
    let addr = {
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        probe.local_addr().unwrap().to_string()
        // listener drops here; the port has no listener when loadgen dials
    };
    let cfg = LoadgenConfig {
        addr,
        connections: 1,
        connect_attempts: 2,
        connect_backoff: Duration::from_millis(1),
        ..LoadgenConfig::default()
    };
    let t0 = Instant::now();
    let err = loadgen::run(&cfg).unwrap_err();
    assert!(matches!(err, Error::Protocol(_)), "{err}");
    let msg = format!("{err}");
    assert!(msg.contains("after 2 attempts"), "schedule named in the error: {msg}");
    assert!(t0.elapsed() < Duration::from_secs(30), "bounded retry, not a spin");
}

#[test]
fn multi_engine_server_routes_a_flat_namespace() {
    // One server fronting two engines: clients see engine 0's streams
    // and groups first, then engine 1's. Independent local twins of
    // each engine are the bit-exactness oracle.
    let server = Server::start_multi(
        vec![
            source(Engine::Native, 2, 4, 4, u64::MAX / 2), // streams 0..8,  groups 0..2
            source(Engine::Sharded, 3, 4, 4, u64::MAX / 2), // streams 8..20, groups 2..5
        ],
        "127.0.0.1:0",
        ServeConfig::default(),
    )
    .unwrap();
    let local_a = source(Engine::Native, 2, 4, 4, u64::MAX / 2);
    let local_b = source(Engine::Sharded, 3, 4, 4, u64::MAX / 2);
    let remote = RemoteSource::connect(server.local_addr()).unwrap();
    assert_eq!(remote.n_streams(), 20);
    assert_eq!(remote.n_groups(), 5);
    assert_eq!(remote.info().engine, "multi");
    for g in 0..5usize {
        let expect = if g < 2 {
            local_a.fetch_block(g, 8).unwrap()
        } else {
            local_b.fetch_block(g - 2, 8).unwrap()
        };
        assert_eq!(remote.fetch_block(g, 8).unwrap(), expect, "group {g} routes bit-exact");
    }
    // Streams rebase across the boundary too (global 10 = engine B's 2).
    let mut got = vec![0u32; 6];
    remote.fetch(10, &mut got).unwrap();
    let mut expect = vec![0u32; 6];
    local_b.fetch(2, &mut expect).unwrap();
    assert_eq!(got, expect, "stream fetch across the engine boundary");
    // Server-side resolve failures carry the *summed* totals (a raw
    // client bypasses RemoteSource's local validation).
    let client = RemoteClient::connect(server.local_addr()).unwrap();
    let req = client.submit_fill(&Request::stream(20).rows(1), 1).unwrap();
    assert_eq!(
        client.next_chunk(req).unwrap().result.unwrap_err(),
        Error::UnknownStream { stream: 20, have: 20 }
    );
    let req = client.submit_fill(&Request::group(5).rows(1), 1).unwrap();
    assert_eq!(
        client.next_chunk(req).unwrap().result.unwrap_err(),
        Error::GroupOutOfRange { group: 5, have: 5 }
    );
    client.bye().unwrap();
}

#[test]
fn shaped_fetches_over_the_wire_match_the_shaped_oracle() {
    // DESIGN.md §7: DATA carries shaped rows; shaping server-side must
    // be bit-identical to shaping the same raw fetch locally, on both
    // engines, for group and stream targets, continuous and discrete
    // families.
    let normal = DistSpec::Normal { mean: 0.0, std: 1.0 };
    for engine in [Engine::Native, Engine::Sharded] {
        let server = serve(source(engine, 2, 4, 4, u64::MAX / 2));
        let remote = RemoteSource::connect(server.local_addr()).unwrap();
        // 6 shaped rows consume 12 raw rows (Box–Muller k = 2); the
        // follow-up continues at shaped row 6 = raw row 12.
        assert_eq!(
            remote.fetch_shaped(ReqTarget::Group(0), 6, normal).unwrap(),
            shaped_oracle(normal, 0, 4, 0, 6)
        );
        assert_eq!(
            remote.fetch_shaped(ReqTarget::Group(0), 2, normal).unwrap(),
            shaped_oracle(normal, 0, 4, 6, 2),
            "shaped fetches advance the raw cursor by draws, not rows"
        );
        // Stream target: lane width 1, scalar oracle.
        let exp = DistSpec::Exponential { rate: 2.0 };
        let mut s = ThunderingStream::new(splitmix64(42 ^ 1), 5);
        let raw: Vec<u32> = (0..10).map(|_| s.next_u32()).collect();
        assert_eq!(
            remote.fetch_shaped(ReqTarget::Stream(5), 5, exp).unwrap(),
            shape_words(exp, &raw, 1),
            "stream-target shaping over the wire"
        );
        // A discrete family crosses as one word per sample.
        let bern = DistSpec::Bernoulli { p: 0.5 };
        let got = remote.fetch_shaped(ReqTarget::Group(1), 4, bern).unwrap();
        assert_eq!(got, shaped_oracle(bern, 1, 4, 0, 4));
        assert_eq!(got.len(), 16, "4 rows × lane width 4 × 1 word");
    }
}

#[test]
fn shaped_lease_resumption_replays_shaped_rows_bit_identically() {
    // The shaped twin of lease_resumption_replays_lost_rows_bit_identically:
    // retention and the resume cursor are keyed on (target, spec), count
    // shaped rows, and the ring holds shaped words — so a reconnecting
    // client replays the exact shaped tail the dead connection lost.
    let spec = DistSpec::Normal { mean: 1.0, std: 0.5 };
    let server = serve(source(Engine::Native, 1, 4, 4, u64::MAX / 2));
    let conn1 = RemoteClient::connect(server.local_addr()).unwrap();
    assert_eq!(
        conn1.lease_resume_shaped(ReqTarget::Group(0), 0, Some(spec)).unwrap(),
        0,
        "fresh shaped track"
    );
    assert_eq!(
        conn1.fill(&Request::group(0).rows(8).dist(spec)).unwrap(),
        shaped_oracle(spec, 0, 4, 0, 8)
    );
    drop(conn1); // dies mid-lease, no BYE
    server.wait_sessions_closed(1);

    let conn2 = RemoteClient::connect(server.local_addr()).unwrap();
    assert_eq!(
        conn2.lease_resume_shaped(ReqTarget::Group(0), 0, Some(spec)).unwrap(),
        8,
        "the shaped cursor counts shaped rows"
    );
    assert_eq!(
        conn2.fill(&Request::group(0).rows(12).dist(spec)).unwrap(),
        shaped_oracle(spec, 0, 4, 0, 12),
        "shaped replay prefix + fresh remainder stitch into one chunk"
    );
    assert_eq!(
        conn2.fill(&Request::group(0).rows(4).dist(spec)).unwrap(),
        shaped_oracle(spec, 0, 4, 12, 4),
        "fresh shaped generation continues past the stitched fill"
    );
    conn2.bye().unwrap();
    server.wait_sessions_closed(2);
}

#[test]
fn shaped_resumption_survives_a_dropped_connection_bit_identically() {
    use std::io::{Read, Write};
    use std::sync::mpsc;

    // The shaped twin of the proxy-kill test: fetch_shaped through
    // RemoteSource::with_resumption must reconnect, re-LEASE under the
    // (target, spec) key, and continue the shaped sequence bit-exactly.
    let server = serve(source(Engine::Native, 1, 4, 4, u64::MAX / 2));
    let upstream = server.local_addr();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let proxy_addr = listener.local_addr().unwrap();
    let (kill_tx, kill_rx) = mpsc::channel::<()>();
    std::thread::spawn(move || {
        for inbound in listener.incoming() {
            let Ok(client_side) = inbound else { break };
            let Ok(server_side) = TcpStream::connect(upstream) else { break };
            let kill_c = client_side.try_clone().unwrap();
            let kill_s = server_side.try_clone().unwrap();
            let back = (server_side.try_clone().unwrap(), client_side.try_clone().unwrap());
            let pump = |mut from: TcpStream, mut to: TcpStream| {
                move || {
                    let mut buf = [0u8; 4096];
                    loop {
                        match from.read(&mut buf) {
                            Ok(0) | Err(_) => break,
                            Ok(n) => {
                                if to.write_all(&buf[..n]).is_err() {
                                    break;
                                }
                            }
                        }
                    }
                    let _ = to.shutdown(std::net::Shutdown::Both);
                }
            };
            std::thread::spawn(pump(client_side, server_side));
            std::thread::spawn(pump(back.0, back.1));
            match kill_rx.recv() {
                Ok(()) => {
                    let _ = kill_c.shutdown(std::net::Shutdown::Both);
                    let _ = kill_s.shutdown(std::net::Shutdown::Both);
                }
                Err(_) => break, // test over; leave the last connection be
            }
        }
    });

    let spec = DistSpec::Exponential { rate: 1.5 };
    let remote = RemoteSource::connect(proxy_addr)
        .unwrap()
        .with_resumption(10, Duration::from_millis(20));
    assert_eq!(
        remote.fetch_shaped(ReqTarget::Group(0), 8, spec).unwrap(),
        shaped_oracle(spec, 0, 4, 0, 8)
    );

    kill_tx.send(()).unwrap();
    std::thread::sleep(Duration::from_millis(100)); // let the kill land
    assert_eq!(
        remote.fetch_shaped(ReqTarget::Group(0), 8, spec).unwrap(),
        shaped_oracle(spec, 0, 4, 8, 8),
        "bit-identical shaped continuation across the dropped connection"
    );
    assert_eq!(
        remote.fetch_shaped(ReqTarget::Group(0), 4, spec).unwrap(),
        shaped_oracle(spec, 0, 4, 16, 4)
    );
    drop(remote);
    server.wait_sessions_closed(2);
}

#[test]
fn oversized_fetches_fail_typed_before_touching_the_wire() {
    let server = serve(source(Engine::Native, 1, 4, 4, u64::MAX / 2));
    let remote = RemoteSource::connect(server.local_addr()).unwrap();
    let max = remote.info().max_fill;
    let mut big = vec![0u32; max as usize + 1];
    assert!(matches!(
        remote.fetch(0, &mut big).unwrap_err(),
        Error::InvalidConfig(_)
    ));
    // The connection is still healthy afterwards.
    assert_eq!(remote.fetch_block(0, 4).unwrap(), oracle_block(0, 4, 0, 4));
}
