//! Integration: the coordinator serving from real AOT artifacts via the
//! PJRT device thread, checked bit-for-bit against the native engine.
//! Requires the `xla` feature (real PJRT bindings) plus `make artifacts`.

#![cfg(feature = "xla")]

use std::sync::Arc;

use thundering::coordinator::Coordinator;
use thundering::prng::{splitmix64, Prng32, ThunderingStream};
use thundering::{Engine, EngineBuilder};

fn artifacts_dir() -> String {
    std::env::var("THUNDERING_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")))
}

fn build(engine: Engine, n_streams: u64) -> Coordinator {
    EngineBuilder::new(n_streams)
        .engine(engine)
        .group_width(64)
        .rows_per_tile(1024)
        .build_coordinator()
        .unwrap()
}

fn pjrt_engine() -> Engine {
    Engine::Pjrt { artifacts_dir: artifacts_dir() }
}

#[test]
fn pjrt_coordinator_matches_native() {
    let pjrt = build(pjrt_engine(), 128);
    let native = build(Engine::Native, 128);
    assert_eq!(pjrt.artifact(), Some("thundering_b1024_p64"));

    for stream in [0u64, 1, 63, 64, 127] {
        let mut a = vec![0u32; 2500];
        let mut b = vec![0u32; 2500];
        pjrt.fetch(stream, &mut a).unwrap();
        native.fetch(stream, &mut b).unwrap();
        assert_eq!(a, b, "stream {stream}");
    }
}

#[test]
fn pjrt_group_block_matches_scalar_oracle() {
    let c = build(pjrt_engine(), 64);
    let block = c.fetch_block(0, 2048).unwrap();
    // Column j of group 0 is stream j, seeded splitmix64(42 ^ 0).
    for j in [0usize, 13, 63] {
        let mut s = ThunderingStream::new(splitmix64(42), j as u64);
        for r in 0..2048 {
            assert_eq!(block[r * 64 + j], s.next_u32(), "row {r} stream {j}");
        }
    }
}

#[test]
fn pjrt_concurrent_clients_ordered_delivery() {
    let c = Arc::new(build(pjrt_engine(), 256));
    let mut handles = Vec::new();
    for t in 0..16u64 {
        let c = c.clone();
        handles.push(std::thread::spawn(move || {
            let stream = t * 16;
            let mut got = Vec::new();
            let mut buf = vec![0u32; 777];
            for _ in 0..3 {
                c.fetch(stream, &mut buf).unwrap();
                got.extend_from_slice(&buf);
            }
            (stream, got)
        }));
    }
    for h in handles {
        let (stream, got) = h.join().unwrap();
        let g = stream / 64;
        let mut s = ThunderingStream::new(splitmix64(42 ^ g), stream);
        let expect: Vec<u32> = (0..got.len()).map(|_| s.next_u32()).collect();
        assert_eq!(got, expect, "stream {stream}");
    }
    let m = c.metrics();
    assert!(m.tiles_executed >= 3, "{m}");
    assert_eq!(m.numbers_delivered, 16 * 3 * 777);
}

#[test]
fn metrics_track_backend_time() {
    let c = build(pjrt_engine(), 64);
    let _ = c.fetch_block(0, 1024).unwrap();
    let m = c.metrics();
    assert_eq!(m.tiles_executed, 1);
    assert!(m.backend_ns > 0);
}
