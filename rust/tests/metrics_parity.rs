//! Cross-engine metrics parity (ISSUE 9 satellite).
//!
//! The consumer-side service counters — numbers delivered, fetch
//! hits/misses, lag rejections — are accounted inside the shared drain
//! core (`coordinator::drain`), so an identical fetch sequence must
//! produce identical counts on the native and sharded engines. The
//! producer-side counters (tiles executed, rows generated, backend
//! time) are intentionally excluded: the sharded engine prefetches
//! ahead of consumption, so those depend on worker timing, not on what
//! clients observed.
//!
//! This pins the engine-agnostic hit/miss contract, including the
//! block fast path: a streamed block counts one fetch miss per block
//! on both engines (the gap this satellite closed — the fast path
//! previously bypassed hit/miss accounting entirely).

use thundering::{Engine, EngineBuilder, StreamSource};

fn build(engine: Engine) -> Box<dyn StreamSource> {
    EngineBuilder::new(8)
        .engine(engine)
        .group_width(4)
        .rows_per_tile(8)
        .lag_window(64)
        .shards(2)
        .build()
        .expect("engine builds")
}

/// Drive one fixed fetch sequence — per-lane hits and misses, a lag
/// rejection, a multi-tile block, and a batched fetch — and return the
/// consumer-side counters.
fn drive(source: &dyn StreamSource) -> (u64, u64, u64, u64) {
    // Lane 0 of group 0 buffers 3 tiles (miss); lanes 1..4 then ride
    // the buffer (hits).
    let mut buf24 = vec![0u32; 24];
    source.fetch(0, &mut buf24).expect("lane 0");
    let mut buf8 = vec![0u32; 8];
    source.fetch(1, &mut buf8).expect("lane 1 head");
    source.fetch(2, &mut buf24).expect("lane 2");
    source.fetch(3, &mut buf24).expect("lane 3");
    let mut buf16 = vec![0u32; 16];
    source.fetch(1, &mut buf16).expect("lane 1 tail");
    // Group 0 now sits uniformly at row 24 with nothing buffered. A
    // 72-row fetch would stretch the spread past the 64-row window.
    let mut buf72 = vec![0u32; 72];
    assert!(source.fetch(0, &mut buf72).is_err(), "lag rejection expected");
    // Untouched group 1 takes the block fast path (2 whole tiles).
    let block = source.fetch_block(1, 16).expect("group 1 block");
    assert_eq!(block.len(), 16 * 4);
    // Batched fetch: both groups are clean-boundary streamable now.
    let many = source.fetch_many(8).expect("fetch_many");
    assert_eq!(many.len(), 2);

    let m = source.metrics();
    (m.numbers_delivered, m.fetch_hits, m.fetch_misses, m.lag_rejections)
}

#[test]
fn consumer_side_counters_are_engine_agnostic() {
    let native = drive(&*build(Engine::Native));
    let sharded = drive(&*build(Engine::Sharded));
    assert_eq!(native, sharded, "(delivered, hits, misses, lag_rejections)");
    // And pin the absolute expectation so the accounting itself (not
    // just its parity) is under test: 5 per-lane fetches = 1 miss + 4
    // hits; the 16-row block and each group's fetch_many block = 3
    // more misses; 24+8+24+24+16 lane numbers + (16+8+8)×4 block
    // numbers = 224 delivered; 1 lag rejection.
    assert_eq!(native, (224, 4, 4, 1));
}

#[test]
fn rejected_fetches_count_on_both_engines_without_consuming() {
    for engine in [Engine::Native, Engine::Sharded] {
        let source = build(engine);
        let mut ok = vec![0u32; 8];
        source.fetch(0, &mut ok).expect("within the window");
        let mut too_big = vec![0u32; 80];
        assert!(source.fetch(0, &mut too_big).is_err());
        assert!(source.fetch_block(0, 80).is_err(), "skewed group, 80 > window 64");
        let m = source.metrics();
        assert_eq!(m.lag_rejections, 2, "{}", source.engine_kind());
        assert_eq!(m.numbers_delivered, 8, "rejections consumed nothing");
    }
}
