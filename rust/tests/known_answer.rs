//! Known-answer tests for the full comparator-PRNG roster (Table 1), plus
//! a generic `fill_u32`-vs-`next_u32` equivalence sweep over every
//! `Prng32` implementation.
//!
//! Vector provenance (see DESIGN.md §2): each constant was produced by an
//! implementation *independent of this crate* — the canonical C reference
//! code of the algorithm's authors where published vectors exist (MT19937
//! `mt19937ar.out`, Random123 Philox kats, Vigna's xoroshiro128**,
//! L'Ecuyer's MRG32k3a checks), cross-validated against a Python oracle
//! (numpy's legacy `RandomState` for MT19937's `init_by_array` seeding).
//! Where the repo uses a parameterization without a published vector
//! (PCG output-before-advance, LFSR113 from an all-12345 state, the
//! Marsaglia xor128 recurrence), the vectors come from the same
//! independent Python transcription of the published recurrences.

use thundering::prng::thundering::{Ablation, AblatedStream};
use thundering::prng::{
    splitmix64, Lcg64, LutSr, Mrg32k3a, Mt19937, PcgXshRr64, PcgXshRs64, Philox4x32, Prng32,
    SplitMix64, ThunderingStream, Xoroshiro128StarStar, Xorshift128,
};

fn first_n(gen: &mut dyn Prng32, n: usize) -> Vec<u32> {
    (0..n).map(|_| gen.next_u32()).collect()
}

#[test]
fn mt19937_matches_authors_init_by_array_vector() {
    // mt19937ar.out (Matsumoto & Nishimura), init_by_array
    // {0x123, 0x234, 0x345, 0x456}; cross-checked with numpy RandomState.
    let mut g = Mt19937::new_by_array(&[0x123, 0x234, 0x345, 0x456]);
    let expect: [u32; 10] = [
        1067595299, 955945823, 477289528, 4107218783, 4228976476, 3344332714, 3355579695,
        227628506, 810200273, 2591290167,
    ];
    assert_eq!(first_n(&mut g, 10), expect);
}

#[test]
fn mt19937_matches_default_seed_vector() {
    // The classic seed-5489 sequence (identical to C++ std::mt19937).
    let mut g = Mt19937::new(5489);
    let expect: [u32; 5] = [3499211612, 581869302, 3890346734, 3586334585, 545404204];
    assert_eq!(first_n(&mut g, 5), expect);
}

#[test]
fn philox4x32_matches_random123_kat_vectors() {
    use thundering::prng::philox::philox4x32_10;
    // Official Random123 known-answer tests for philox4x32-10.
    assert_eq!(
        philox4x32_10([0, 0, 0, 0], [0, 0]),
        [0x6627_E8D5, 0xE169_C58D, 0xBC57_AC4C, 0x9B00_DBD8]
    );
    assert_eq!(
        philox4x32_10([u32::MAX; 4], [u32::MAX; 2]),
        [0x408F_276D, 0x41C8_3B0E, 0xA20B_C7C6, 0x6D54_51FD]
    );
    // Stream form: block 1 of key (7, 99) continues the counter sequence.
    let mut s = Philox4x32::new([7, 99]);
    let _ = first_n(&mut s, 4); // drain block 0
    assert_eq!(first_n(&mut s, 4), [4261944098, 4095783935, 919678452, 1392150649]);
}

#[test]
fn mrg32k3a_matches_lecuyer_reference_sequence() {
    // From the canonical all-12345 starting state; the raw outputs match
    // L'Ecuyer's published u_n = z_n/(m1+1) check values (0.127011,
    // 0.318528, 0.309186, ...); these are the 32-bit scaled outputs.
    let mut g = Mrg32k3a::from_state([12345; 3], [12345; 3]);
    let expect: [u32; 6] =
        [545508615, 1368065476, 1327943825, 3546985268, 951893240, 2290915747];
    assert_eq!(first_n(&mut g, 6), expect);
}

#[test]
fn xoroshiro128starstar_matches_vigna_reference() {
    // u64 outputs from state (1, 2) per the canonical C implementation,
    // delivered 32 bits at a time (low half first).
    let mut g = Xoroshiro128StarStar::from_state(1, 2);
    let expect: [u32; 6] = [5760, 0, 3279963008, 22, 17280, 2260054957];
    assert_eq!(first_n(&mut g, 6), expect);
}

#[test]
fn pcg_xsh_rs_matches_oracle_vector() {
    // PCG-XSH-RS-64/32, output-before-advance, seed 42 / stream 0
    // (inc = 1): independent Python transcription of O'Neill's recurrence.
    let mut g = PcgXshRs64::new(42, 0);
    let expect: [u32; 6] = [0, 3104263596, 8360134, 3669367720, 2256410373, 2956640566];
    assert_eq!(first_n(&mut g, 6), expect);
}

#[test]
fn pcg_xsh_rr_matches_oracle_vector() {
    let mut g = PcgXshRr64::new(42, 0);
    let expect: [u32; 6] = [0, 210066564, 812384312, 2560358063, 3425943684, 3613413895];
    assert_eq!(first_n(&mut g, 6), expect);
}

#[test]
fn tausworthe_lfsr113_matches_oracle_vector() {
    // LFSR113 stepped from the all-12345 state (valid: every component
    // above its minimum), via an independent transcription of L'Ecuyer's
    // published C code.
    let mut g = LutSr::from_state([12345; 4]);
    let expect: [u32; 6] =
        [3338197162, 227261592, 1979908174, 147202595, 2208502443, 1347239434];
    assert_eq!(first_n(&mut g, 6), expect);
}

#[test]
fn xorshift128_matches_marsaglia_seed_vector() {
    // Marsaglia's xor128 with his paper's seed (123456789, 362436069,
    // 521288629, 88675123).
    let mut g = Xorshift128::new([123456789, 362436069, 521288629, 88675123]);
    let expect: [u32; 6] =
        [3701687786, 458299110, 2500872618, 3633119408, 516391518, 2377269574];
    assert_eq!(first_n(&mut g, 6), expect);
}

#[test]
fn xorshift128_matches_python_oracle_from_master_seed() {
    // From the project's master seed (params.XS128_SEED) — the same
    // states the Pallas kernels bake in.
    use thundering::prng::xorshift::XS128_SEED;
    let mut g = Xorshift128::new(XS128_SEED);
    let expect: [u32; 6] =
        [3218796604, 1669865808, 2632967159, 1140209258, 734360888, 157635505];
    assert_eq!(first_n(&mut g, 6), expect);
}

#[test]
fn lcg64_matches_oracle_vector() {
    // High-32 truncation of x' = a·x + 55 from seed 42 (MMIX multiplier).
    let mut g = Lcg64::new(42);
    let expect: [u32; 6] =
        [2104627054, 424312911, 887000589, 4274229869, 228093390, 3745906375];
    assert_eq!(first_n(&mut g, 6), expect);
}

#[test]
fn splitmix64_matches_vigna_reference() {
    let mut g = SplitMix64::new(0);
    assert_eq!(g.next_u64(), 0xE220_A839_7B1D_CDAF);
    assert_eq!(g.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    assert_eq!(g.next_u64(), 0x06C4_5D18_8009_454F);
}

#[test]
fn thundering_stream_matches_python_tile_oracle() {
    // Column 0 of ref.thundering_tile_ref(splitmix64(42), ...) — the same
    // vector the batch/tile tests pin, via the scalar path.
    let mut s = ThunderingStream::new(splitmix64(42), 0);
    assert_eq!(first_n(&mut s, 4), [1809276457, 3112793216, 58361432, 4212462168]);
}

/// Every `Prng32` in the roster must deliver exactly the same sequence
/// through `fill_u32` as through repeated `next_u32` — this is what lets
/// the coordinator, battery, and apps use either interface
/// interchangeably (and guards future buffered/SIMD `fill_u32`
/// overrides).
#[test]
fn fill_u32_equals_next_u32_across_roster() {
    type Factory = Box<dyn Fn() -> Box<dyn Prng32>>;
    let roster: Vec<Factory> = vec![
        Box::new(|| Box::new(ThunderingStream::new(42, 7))),
        Box::new(|| Box::new(AblatedStream::new(42, 7, Ablation::Decorrelation))),
        Box::new(|| Box::new(SplitMix64::new(9))),
        Box::new(|| Box::new(Lcg64::new(9))),
        Box::new(|| Box::new(PcgXshRs64::new(9, 3))),
        Box::new(|| Box::new(PcgXshRr64::new(9, 3))),
        Box::new(|| Box::new(Xoroshiro128StarStar::new(9))),
        Box::new(|| Box::new(Philox4x32::new([9, 3]))),
        Box::new(|| Box::new(Mrg32k3a::new(9))),
        Box::new(|| Box::new(Mt19937::new(9))),
        Box::new(|| Box::new(LutSr::new(9))),
        Box::new(|| Box::new(Xorshift128::new([9, 8, 7, 6]))),
    ];
    for factory in &roster {
        let mut a = factory();
        let mut b = factory();
        let name = a.name().to_string();
        // Two fills with a deliberately odd length so buffered generators
        // (e.g. philox's 4-word blocks, xoroshiro's u64 halves) cross
        // their internal block boundaries mid-buffer.
        for round in 0..2 {
            let mut filled = vec![0u32; 257];
            a.fill_u32(&mut filled);
            let stepped: Vec<u32> = (0..257).map(|_| b.next_u32()).collect();
            assert_eq!(filled, stepped, "{name} round {round}");
        }
    }
}
