//! Randomized property tests on coordinator and generator invariants.
//! (proptest is unavailable offline; cases are driven by our own
//! splitmix64 with fixed seeds, so failures are perfectly reproducible.)

use std::time::Duration;

use thundering::coordinator::StreamRegistry;
use thundering::prng::lcg::{lcg_jump, lcg_step, LCG_A, LCG_C};
use thundering::prng::thundering::leaf_h;
use thundering::prng::xorshift::{pack, unpack, xs128_jump, xs128_step_packed};
use thundering::prng::{splitmix64, Prng32, SplitMix64, ThunderingBatch, ThunderingStream};
use thundering::{Engine, EngineBuilder, Error, Request, StreamSource};

/// Property: any fetch schedule delivers each stream's exact scalar
/// sequence, regardless of interleaving, chunk sizes, and group shape.
#[test]
fn prop_fetch_schedule_preserves_per_stream_order() {
    let mut rng = SplitMix64::new(0xC0FFEE);
    for case in 0..25 {
        let width = [2usize, 4, 8, 16][rng.next_u32() as usize % 4];
        let n_groups = 1 + rng.next_u32() as usize % 3;
        let rows_per_tile = [4usize, 16, 64][rng.next_u32() as usize % 3];
        let n_streams = (width * n_groups) as u64;
        let c = EngineBuilder::new(n_streams)
            .engine(Engine::Native)
            .group_width(width)
            .rows_per_tile(rows_per_tile)
            .lag_window(1 << 14)
            .root_seed(42)
            .build()
            .unwrap();

        let mut delivered: Vec<Vec<u32>> = vec![Vec::new(); n_streams as usize];
        for _ in 0..60 {
            let stream = rng.next_u32() as u64 % n_streams;
            let n = 1 + rng.next_u32() as usize % 50;
            let mut buf = vec![0u32; n];
            // Lag rejections are allowed by the contract; skip those ops.
            if c.fetch(stream, &mut buf).is_ok() {
                delivered[stream as usize].extend_from_slice(&buf);
            }
        }
        for (sid, got) in delivered.iter().enumerate() {
            let g = sid as u64 / width as u64;
            let mut s = ThunderingStream::new(splitmix64(42 ^ g), sid as u64);
            let expect: Vec<u32> = (0..got.len()).map(|_| s.next_u32()).collect();
            assert_eq!(got, &expect, "case {case} stream {sid}");
        }
    }
}

/// Property: the builder rejects every degenerate configuration —
/// randomized over the parameter space so the rejection logic holds for
/// arbitrary (not just hand-picked) bad values.
#[test]
fn prop_builder_rejects_invalid_configs() {
    let mut rng = SplitMix64::new(0xBAD_CFG);
    for _ in 0..50 {
        let width = 1 + rng.next_u32() as usize % 64;
        let rows = 1 + rng.next_u32() as usize % 512;
        let engine =
            if rng.next_u32() % 2 == 0 { Engine::Native } else { Engine::Sharded };

        // Zero streams.
        let e = EngineBuilder::new(0).engine(engine.clone()).build().unwrap_err();
        assert!(matches!(e, Error::InvalidConfig(_)), "{e}");

        // Lag window smaller than one tile of rows.
        let lag = rng.next_u64() % rows as u64; // in 0..rows
        let e = EngineBuilder::new(width as u64)
            .engine(engine.clone())
            .group_width(width)
            .rows_per_tile(rows)
            .lag_window(lag)
            .build()
            .unwrap_err();
        assert!(matches!(e, Error::InvalidConfig(_)), "{e}");

        // Prefetch depth 0.
        let e = EngineBuilder::new(width as u64)
            .engine(engine.clone())
            .group_width(width)
            .prefetch_depth(0)
            .build()
            .unwrap_err();
        assert!(matches!(e, Error::InvalidConfig(_)), "{e}");

        // Stream count not a multiple of the group width.
        if width > 1 {
            let off_by = 1 + rng.next_u64() % (width as u64 - 1);
            let misaligned = width as u64 * (1 + rng.next_u64() % 4) + off_by;
            let e = EngineBuilder::new(misaligned)
                .engine(engine)
                .group_width(width)
                .build()
                .unwrap_err();
            assert!(matches!(e, Error::InvalidConfig(_)), "{e}");
        }
    }
}

/// Property: behind `StreamSource`, the native and sharded engines are
/// bit-identical (including *which calls fail*, and how) under random
/// interleavings of `fetch`, `fetch_block`, and `fetch_many`.
#[test]
fn prop_engines_bit_identical_under_random_interleaving() {
    let mut rng = SplitMix64::new(0xD1CE);
    for case in 0..8 {
        let width = [2usize, 3, 4, 8][rng.next_u32() as usize % 4];
        let n_groups = 1 + rng.next_u32() as usize % 3;
        let rows_per_tile = [4usize, 8, 16][rng.next_u32() as usize % 3];
        let n_streams = (width * n_groups) as u64;
        let seed = rng.next_u64();
        let build = |engine: Engine| -> Box<dyn StreamSource> {
            EngineBuilder::new(n_streams)
                .engine(engine)
                .group_width(width)
                .rows_per_tile(rows_per_tile)
                .lag_window(64) // tight: rejections are part of the contract
                .root_seed(seed)
                .build()
                .unwrap()
        };
        let native = build(Engine::Native);
        let sharded = build(Engine::Sharded);

        for op in 0..60 {
            match rng.next_u32() % 4 {
                0 | 1 => {
                    let stream = rng.next_u64() % n_streams;
                    let n = 1 + rng.next_u32() as usize % 50;
                    let mut a = vec![0u32; n];
                    let mut b = vec![0u32; n];
                    let ra = native.fetch(stream, &mut a);
                    let rb = sharded.fetch(stream, &mut b);
                    assert_eq!(ra, rb, "case {case} op {op}: fetch({stream}, {n})");
                    assert_eq!(a, b, "case {case} op {op}: fetch({stream}, {n}) payload");
                }
                2 => {
                    let group = rng.next_u64() as usize % n_groups;
                    let rows = 1 + rng.next_u32() as usize % 40;
                    let ra = native.fetch_block(group, rows);
                    let rb = sharded.fetch_block(group, rows);
                    assert_eq!(ra, rb, "case {case} op {op}: fetch_block({group}, {rows})");
                }
                _ => {
                    let rows = 1 + rng.next_u32() as usize % 24;
                    let ra = native.fetch_many(rows);
                    let rb = sharded.fetch_many(rows);
                    assert_eq!(ra, rb, "case {case} op {op}: fetch_many({rows})");
                }
            }
        }
    }
}

/// What the lifecycle mix did to one submitted request.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Fate {
    /// Left alone (or armed with a deadline far too generous to fire):
    /// must deliver `Ok`.
    Normal,
    /// Cancelled via its handle right after submission: resolves as
    /// `Err(Cancelled)` — unless an engine worker won the race and
    /// executed it first, in which case its real data is delivered.
    Cancelled,
    /// Armed with an already-expired deadline: the sweep retires it
    /// before any executor can claim it, deterministically —
    /// `Err(DeadlineExceeded)`, never data, never lost.
    Expired,
}

/// Property (the request-lifecycle contract, both engines): a
/// randomized mix of normal / cancelled / expired submissions across
/// block- and lane-targeted groups preserves exactly-once delivery,
/// per-group FIFO among the survivors, and bit-identical scalar replay
/// of everything actually delivered — a dead request consumes no
/// stream state, so the survivors' concatenation is always a
/// contiguous oracle prefix.
#[test]
fn prop_lifecycle_mix_preserves_exactly_once_fifo_and_replay() {
    let mut rng = SplitMix64::new(0x11F3_C1C1);
    for engine in [Engine::Native, Engine::Sharded] {
        for case in 0..4 {
            let width = [2usize, 4][rng.next_u32() as usize % 2];
            let n_groups = 2 + rng.next_u32() as usize % 3;
            let rows_per_tile = [4usize, 8][rng.next_u32() as usize % 2];
            let seed = rng.next_u64();
            let cq = EngineBuilder::new((n_groups * width) as u64)
                .engine(engine.clone())
                .group_width(width)
                .rows_per_tile(rows_per_tile)
                .lag_window(u64::MAX / 2)
                .root_seed(seed)
                .build_completion()
                .unwrap();

            // Half the groups serve whole-group blocks, half a single
            // fixed lane — so each group's Ok payloads concatenate to
            // one well-defined scalar oracle prefix.
            let lane_of: Vec<Option<u64>> = (0..n_groups)
                .map(|g| {
                    (rng.next_u32() % 2 == 0)
                        .then(|| (g * width) as u64 + rng.next_u64() % width as u64)
                })
                .collect();

            let mut submissions = Vec::new(); // ticket order == submission order
            for _ in 0..40 {
                let g = rng.next_u32() as usize % n_groups;
                let rows = 1 + rng.next_u32() as usize % 20;
                let base = match lane_of[g] {
                    Some(lane) => Request::stream(lane).rows(rows),
                    None => Request::group(g).rows(rows),
                };
                let (req, fate) = match rng.next_u32() % 4 {
                    0 => (base, Fate::Cancelled),
                    1 => (base.deadline(Duration::ZERO), Fate::Expired),
                    2 => (base.deadline(Duration::from_secs(600)), Fate::Normal),
                    _ => (base, Fate::Normal),
                };
                let (ticket, handle) = cq.submit(req).unwrap();
                if fate == Fate::Cancelled {
                    handle.cancel();
                }
                submissions.push((ticket, g, rows, fate));
            }

            let mut results = std::collections::HashMap::new();
            for c in cq.wait_all(None) {
                assert!(
                    results.insert(c.ticket, c.result).is_none(),
                    "case {case}: ticket delivered twice"
                );
            }
            assert_eq!(
                results.len(),
                submissions.len(),
                "case {case}: every ticket resolves exactly once"
            );
            assert_eq!(cq.outstanding(), 0);

            // Replay every group's Ok payloads, in submission order,
            // against its scalar oracle.
            let mut block_oracles: Vec<ThunderingBatch> = (0..n_groups)
                .map(|g| {
                    ThunderingBatch::new(
                        splitmix64(seed ^ g as u64),
                        width,
                        (g * width) as u64,
                    )
                })
                .collect();
            let mut lane_oracles: Vec<Option<ThunderingStream>> = (0..n_groups)
                .map(|g| {
                    lane_of[g]
                        .map(|lane| ThunderingStream::new(splitmix64(seed ^ g as u64), lane))
                })
                .collect();
            for (ticket, g, rows, fate) in submissions {
                match results.remove(&ticket).expect("resolved above") {
                    Ok(values) => {
                        assert_ne!(
                            fate,
                            Fate::Expired,
                            "case {case}: an already-expired request must never execute"
                        );
                        // Normal, or a cancel that lost the race to an
                        // engine worker: either way the payload must be
                        // the group's next contiguous oracle rows.
                        let expect = match &mut lane_oracles[g] {
                            Some(s) => (0..values.len()).map(|_| s.next_u32()).collect(),
                            None => block_oracles[g].tile(rows),
                        };
                        assert_eq!(
                            values, expect,
                            "case {case}: survivor FIFO / replay broke on group {g}"
                        );
                    }
                    Err(Error::Cancelled) => {
                        assert_eq!(fate, Fate::Cancelled, "case {case}: spurious cancel")
                    }
                    Err(Error::DeadlineExceeded) => assert_eq!(
                        fate,
                        Fate::Expired,
                        "case {case}: spurious expiry (600 s deadlines must not fire)"
                    ),
                    Err(e) => panic!("case {case}: unexpected error {e}"),
                }
            }
        }
    }
}

/// Property (DESIGN.md §7): shaped fills are bit-identical across the
/// native and sharded engines AND equal to shaping the raw scalar
/// oracle directly — for random specs, targets, row counts, and
/// interleavings with raw fills on the same groups. Shaping is a pure
/// function of the raw tiles, so where the shaping runs (shard thread
/// vs consumer thread) must never show in the payload.
#[test]
fn prop_shaped_fills_bit_identical_across_engines_and_oracle() {
    use thundering::dist::shape_words;
    use thundering::DistSpec;
    let specs = [
        DistSpec::Uniform01,
        DistSpec::UniformRange { lo: -1.0, hi: 3.0 },
        DistSpec::Normal { mean: 0.0, std: 1.0 },
        DistSpec::Exponential { rate: 1.5 },
        DistSpec::Bernoulli { p: 0.4 },
        DistSpec::Poisson { rate: 3.0 },
    ];
    let mut rng = SplitMix64::new(0x5AFE_D157);
    for case in 0..6 {
        let width = [2usize, 4][rng.next_u32() as usize % 2];
        let n_groups = 1 + rng.next_u32() as usize % 3;
        let seed = rng.next_u64();
        let build = |engine: Engine| {
            EngineBuilder::new((n_groups * width) as u64)
                .engine(engine)
                .group_width(width)
                .rows_per_tile(8)
                .lag_window(u64::MAX / 2)
                .root_seed(seed)
                .build_completion()
                .unwrap()
        };
        let native = build(Engine::Native);
        let sharded = build(Engine::Sharded);

        // As in the lifecycle property: a group serves either whole-group
        // blocks or one fixed lane, so each group's raw consumption is a
        // single well-defined oracle sequence.
        let lane_of: Vec<Option<u64>> = (0..n_groups)
            .map(|g| {
                (rng.next_u32() % 2 == 0)
                    .then(|| (g * width) as u64 + rng.next_u64() % width as u64)
            })
            .collect();
        let mut block_oracles: Vec<ThunderingBatch> = (0..n_groups)
            .map(|g| {
                ThunderingBatch::new(splitmix64(seed ^ g as u64), width, (g * width) as u64)
            })
            .collect();
        let mut lane_oracles: Vec<Option<ThunderingStream>> = (0..n_groups)
            .map(|g| {
                lane_of[g].map(|lane| ThunderingStream::new(splitmix64(seed ^ g as u64), lane))
            })
            .collect();

        for op in 0..24 {
            let g = rng.next_u32() as usize % n_groups;
            let rows = 1 + rng.next_u32() as usize % 12;
            // Every 4th op stays raw so shaped and raw fills interleave
            // on the same stream state.
            let spec = (rng.next_u32() % 4 != 0)
                .then(|| specs[rng.next_u32() as usize % specs.len()]);
            let k = spec.map_or(1, |d| d.draws_per_row());
            let (raw, shape_width) = match &mut lane_oracles[g] {
                Some(s) => ((0..rows * k).map(|_| s.next_u32()).collect::<Vec<u32>>(), 1),
                None => (block_oracles[g].tile(rows * k), width),
            };
            let expect = match spec {
                Some(d) => shape_words(d, &raw, shape_width),
                None => raw,
            };
            let request = || {
                let base = match lane_of[g] {
                    Some(lane) => Request::stream(lane).rows(rows),
                    None => Request::group(g).rows(rows),
                };
                base.dist_opt(spec)
            };
            for (name, cq) in [("native", &native), ("sharded", &sharded)] {
                let (ticket, _) = cq.submit(request()).unwrap();
                let c = cq.wait_for(ticket, None).unwrap().expect("sole consumer");
                assert_eq!(c.dist, spec, "case {case} op {op} {name}: dist echo");
                let values = c.result.unwrap();
                assert_eq!(
                    values, expect,
                    "case {case} op {op} {name}: group {g} rows {rows} spec {spec:?}"
                );
            }
        }
    }
}

/// Property: lag-window rejections never corrupt subsequent delivery.
#[test]
fn prop_lag_rejection_is_clean() {
    let mut rng = SplitMix64::new(7);
    for _ in 0..10 {
        let c = EngineBuilder::new(2)
            .engine(Engine::Native)
            .group_width(2)
            .rows_per_tile(8)
            .lag_window(32)
            .root_seed(1)
            .build()
            .unwrap();
        let mut got0 = Vec::new();
        for _ in 0..30 {
            let n = 1 + rng.next_u32() as usize % 40;
            let mut buf = vec![0u32; n];
            if c.fetch(0, &mut buf).is_ok() {
                got0.extend_from_slice(&buf);
            }
        }
        let mut s = ThunderingStream::new(splitmix64(1), 0);
        let expect: Vec<u32> = (0..got0.len()).map(|_| s.next_u32()).collect();
        assert_eq!(got0, expect);
    }
}

/// Property: registry h values are globally unique and even across random
/// registration batch sizes.
#[test]
fn prop_registry_h_unique_even() {
    let mut rng = SplitMix64::new(99);
    let mut reg = StreamRegistry::new();
    let mut seen = std::collections::HashSet::new();
    for _ in 0..50 {
        let n = 1 + rng.next_u32() as u64 % 100;
        for spec in reg.register(n).unwrap() {
            assert_eq!(spec.h % 2, 0);
            assert_eq!(spec.h, leaf_h(spec.id));
            assert!(seen.insert(spec.h), "duplicate h for id {}", spec.id);
        }
    }
}

/// Property: LCG jump-ahead composes for random jump sizes.
#[test]
fn prop_lcg_jump_composition() {
    let mut rng = SplitMix64::new(5);
    for _ in 0..200 {
        let x = rng.next_u64();
        let j = rng.next_u64() % 100_000;
        let k = rng.next_u64() % 100_000;
        let a = lcg_jump(lcg_jump(x, j, LCG_A, LCG_C), k, LCG_A, LCG_C);
        let b = lcg_jump(x, j + k, LCG_A, LCG_C);
        assert_eq!(a, b);
    }
}

/// Property: LCG jump-ahead equals explicit stepping for random small k.
#[test]
fn prop_lcg_jump_equals_steps() {
    let mut rng = SplitMix64::new(6);
    for _ in 0..50 {
        let x0 = rng.next_u64();
        let k = rng.next_u64() % 3000;
        let mut x = x0;
        for _ in 0..k {
            x = lcg_step(x);
        }
        assert_eq!(lcg_jump(x0, k, LCG_A, LCG_C), x);
    }
}

/// Property: xorshift jump equals explicit stepping for random states/k.
#[test]
fn prop_xs128_jump_equals_steps() {
    let mut rng = SplitMix64::new(8);
    for _ in 0..25 {
        let state = [
            rng.next_u32() | 1, // ensure nonzero
            rng.next_u32(),
            rng.next_u32(),
            rng.next_u32(),
        ];
        let k = rng.next_u32() as u128 % 2000;
        let mut s = pack(state);
        for _ in 0..k {
            s = xs128_step_packed(s);
        }
        assert_eq!(xs128_jump(state, k), unpack(s));
    }
}

/// Property: stream jump(k) == k outputs discarded, for random k.
#[test]
fn prop_stream_jump_consistency() {
    let mut rng = SplitMix64::new(10);
    for _ in 0..20 {
        let stream_id = rng.next_u64() % 1000;
        let k = rng.next_u64() % 5000;
        let mut a = ThunderingStream::new(77, stream_id);
        let mut b = ThunderingStream::new(77, stream_id);
        for _ in 0..k {
            a.next_u32();
        }
        b.jump(k);
        assert_eq!(a.next_u32(), b.next_u32(), "stream {stream_id} k {k}");
    }
}

/// Property: substream non-overlap — windows of different streams never
/// collide (probabilistically: no window of 64 outputs repeats across the
/// first 64 streams' first 2^10 outputs).
#[test]
fn prop_no_cross_stream_window_collision() {
    use std::collections::HashSet;
    let mut windows: HashSet<Vec<u32>> = HashSet::new();
    for i in 0..64u64 {
        let mut s = ThunderingStream::new(42, i);
        let out: Vec<u32> = (0..1024).map(|_| s.next_u32()).collect();
        for w in out.chunks_exact(64) {
            assert!(windows.insert(w.to_vec()), "window collision on stream {i}");
        }
    }
}

/// Property: JSON parser round-trips random documents built from our own
/// generator (fuzz-lite).
#[test]
fn prop_json_roundtrip_random_docs() {
    use thundering::util::json::Json;
    let mut rng = SplitMix64::new(11);
    for _ in 0..100 {
        let doc = random_json(&mut rng, 3);
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(back, doc, "{text}");
    }
}

fn random_json(rng: &mut SplitMix64, depth: u32) -> thundering::util::json::Json {
    use thundering::util::json::Json;
    let pick = rng.next_u32() % if depth == 0 { 4 } else { 6 };
    match pick {
        0 => Json::Null,
        1 => Json::Bool(rng.next_u32() % 2 == 0),
        2 => {
            let v = rng.next_u64();
            Json::Num(v as f64, v.to_string())
        }
        3 => Json::Str(format!("s{}", rng.next_u32())),
        4 => {
            let n = rng.next_u32() as usize % 4;
            Json::Arr((0..n).map(|_| random_json(rng, depth - 1)).collect())
        }
        _ => {
            let n = rng.next_u32() as usize % 4;
            Json::Obj(
                (0..n)
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            )
        }
    }
}
