//! Integration: every AOT artifact loads, compiles, and matches the native
//! Rust implementation bit-for-bit. This is the cross-layer contract test —
//! Pallas kernel (via HLO/PJRT) ≡ python oracle ≡ Rust scalar engine.
//! Requires the `xla` feature (real PJRT bindings) plus `make artifacts`.

#![cfg(feature = "xla")]

use thundering::prng::thundering::leaf_h;
use thundering::prng::{splitmix64, ThunderingBatch};
use thundering::runtime::{BsParams, Runtime, TileState};

fn runtime() -> Runtime {
    let dir = std::env::var("THUNDERING_ARTIFACTS").unwrap_or_else(|_| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    });
    Runtime::new(dir).expect("artifacts missing — run `make artifacts`")
}

#[test]
fn thundering_tiles_match_native_batch() {
    let rt = runtime();
    for name in rt.names_of_kind("thundering") {
        let exe = rt.load(&name).unwrap();
        let (p, rows) = (exe.info.p, exe.info.rows);
        let seed = splitmix64(42);
        let mut state = TileState::new(seed, p, 0);
        let mut out = vec![0u32; rows * p];
        exe.run_thundering(&mut state, &mut out).unwrap();

        let mut native = ThunderingBatch::new(seed, p, 0);
        let expect = native.tile(rows);
        assert_eq!(out, expect, "artifact {name} mismatch vs native");
        assert_eq!(state.root, native.root_state(), "{name} root state");
        assert_eq!(state.xs, native.xs_states(), "{name} xs state");

        // Second invocation continues the stream seamlessly.
        exe.run_thundering(&mut state, &mut out).unwrap();
        let expect2 = native.tile(rows);
        assert_eq!(out, expect2, "artifact {name} tile 2 mismatch");
    }
}

#[test]
fn thundering_scan_matches_native_batch() {
    let rt = runtime();
    for name in rt.names_of_kind("thundering_scan") {
        let exe = rt.load(&name).unwrap();
        let (p, rows) = (exe.info.p, exe.info.rows);
        let seed = splitmix64(7);
        let mut state = TileState::new(seed, p, 0);
        let mut out = vec![0u32; rows * p];
        exe.run_thundering(&mut state, &mut out).unwrap();

        let mut native = ThunderingBatch::new(seed, p, 0);
        let expect = native.tile(rows);
        assert_eq!(out, expect, "artifact {name} mismatch vs native");
        assert_eq!(state.root, native.root_state());
    }
}

#[test]
fn tile_state_offset_streams() {
    let rt = runtime();
    let name = rt.names_of_kind("thundering").into_iter().next().unwrap();
    let exe = rt.load(&name).unwrap();
    let (p, rows) = (exe.info.p, exe.info.rows);
    let first = 1000u64;
    let seed = splitmix64(3);
    let mut state = TileState::new(seed, p, first);
    assert_eq!(state.h[0], leaf_h(first));
    let mut out = vec![0u32; rows * p];
    exe.run_thundering(&mut state, &mut out).unwrap();
    let mut native = ThunderingBatch::new(seed, p, first);
    assert_eq!(out, native.tile(rows));
}

#[test]
fn philox_tile_matches_native() {
    let rt = runtime();
    for name in rt.names_of_kind("philox") {
        let exe = rt.load(&name).unwrap();
        let (p, rows) = (exe.info.p, exe.info.rows);
        let mut out = vec![0u32; rows * p];
        exe.run_philox(5, [7, 99], &mut out).unwrap();
        // Native comparison: stream i = key (7+i, 99), counters from 5.
        use thundering::prng::philox::philox4x32_10;
        for i in 0..p {
            for n in 0..rows / 4 {
                let ctr = 5 + n as u64;
                let r = philox4x32_10(
                    [ctr as u32, (ctr >> 32) as u32, 0, 0],
                    [7 + i as u32, 99],
                );
                for j in 0..4 {
                    assert_eq!(out[(4 * n + j) * p + i], r[j], "philox ({n},{j},{i})");
                }
            }
        }
    }
}

#[test]
fn lcg_only_tile_matches_native() {
    let rt = runtime();
    for name in rt.names_of_kind("lcg_only") {
        let exe = rt.load(&name).unwrap();
        let (p, rows) = (exe.info.p, exe.info.rows);
        let h: Vec<u64> = (0..p as u64).map(leaf_h).collect();
        let mut root = 12345u64;
        let mut out = vec![0u32; rows * p];
        exe.run_lcg_only(&mut root, &h, &mut out).unwrap();
        let mut x = 12345u64;
        for n in 0..rows {
            x = thundering::prng::lcg::lcg_step(x);
            for i in 0..p {
                let w = x.wrapping_add(h[i]);
                assert_eq!(out[n * p + i], (w >> 32) as u32, "lcg ({n},{i})");
            }
        }
        assert_eq!(root, x);
    }
}

#[test]
fn pi_tile_plausible_and_stateful() {
    let rt = runtime();
    let exe = rt.load("pi_tile").unwrap();
    let p = exe.info.p;
    let mut state = TileState::new(splitmix64(42), p, 0);
    let draws = (exe.info.rows / 2) * p;
    let mut total_hits = 0u64;
    let tiles = 8;
    for _ in 0..tiles {
        total_hits += exe.run_pi(&mut state).unwrap() as u64;
    }
    let pi = 4.0 * total_hits as f64 / (tiles * draws) as f64;
    assert!((pi - std::f64::consts::PI).abs() < 0.01, "pi estimate {pi}");
}

#[test]
fn bs_tile_close_to_black_scholes_closed_form() {
    let rt = runtime();
    let exe = rt.load("bs_tile").unwrap();
    let p = exe.info.p;
    let mut state = TileState::new(splitmix64(42), p, 0);
    let params = BsParams::default();
    let draws_per_tile = (exe.info.rows / 2) * p;
    let tiles = 8;
    let mut sum = 0.0f64;
    for _ in 0..tiles {
        sum += exe.run_bs(&mut state, &params).unwrap() as f64;
    }
    let price = sum / (tiles * draws_per_tile) as f64;
    // Closed-form Black-Scholes call for (100, 100, 0.05, 0.2, 1.0) ≈ 10.4506.
    assert!((price - 10.4506).abs() < 0.15, "MC price {price}");
}
