//! Thread-stress determinism for the sharded parallel engine: many
//! concurrent clients fetching interleaved, non-aligned chunk sizes from
//! the sharded engine must receive output **bit-identical** to scalar
//! `ThunderingStream` replay — the cross-shard, prefetching extension of
//! `coordinator::tests::concurrent_fetches_consistent`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use thundering::coordinator::ParallelCoordinator;
use thundering::prng::{splitmix64, Prng32, ThunderingBatch, ThunderingStream};
use thundering::{CompletionQueue, Engine, EngineBuilder, StreamReq, Ticket};

fn build(width: usize, rows: usize, shards: usize, n_streams: u64) -> ParallelCoordinator {
    EngineBuilder::new(n_streams)
        .engine(Engine::Sharded)
        .group_width(width)
        .rows_per_tile(rows)
        .lag_window(u64::MAX / 2)
        .prefetch_depth(2)
        .shards(shards)
        .root_seed(42)
        .build_sharded()
        .unwrap()
}

#[test]
fn sixteen_clients_bit_identical_to_scalar_replay() {
    // 16 groups of 8 streams; 16 clients, each hammering a different
    // (group, lane) pair with varying chunk sizes that straddle the
    // 64-row tile boundary in every possible phase. Shard count is auto
    // (one per core), so groups share shards on small hosts — the
    // interleaving this test is designed to shake out.
    let c = Arc::new(build(8, 64, 0, 128));
    let mut handles = Vec::new();
    for t in 0..16u64 {
        let c = c.clone();
        handles.push(std::thread::spawn(move || {
            let stream = t * 8 + (t % 8);
            let chunks = [257usize, 63, 1024, 1, 500, 129];
            let mut all = Vec::new();
            for (i, &n) in chunks.iter().cycle().take(12).enumerate() {
                let mut buf = vec![0u32; n + (i % 3)];
                c.fetch(stream, &mut buf).unwrap();
                all.extend_from_slice(&buf);
            }
            (stream, all)
        }));
    }
    for h in handles {
        let (stream, got) = h.join().unwrap();
        let g = stream / 8;
        let mut s = ThunderingStream::new(splitmix64(42 ^ g), stream);
        let expect: Vec<u32> = (0..got.len()).map(|_| s.next_u32()).collect();
        assert_eq!(got, expect, "stream {stream}");
    }
}

#[test]
fn clients_sharing_groups_stay_bit_identical() {
    // Two clients per group, different lanes: the drain lock serializes
    // them while the shard prefetches; both lanes must replay exactly.
    let c = Arc::new(build(4, 32, 2, 16));
    let mut handles = Vec::new();
    for t in 0..8u64 {
        let c = c.clone();
        handles.push(std::thread::spawn(move || {
            let stream = (t / 2) * 4 + (t % 2) * 3; // lanes 0 and 3
            let mut all = Vec::new();
            let mut buf = vec![0u32; 377];
            for _ in 0..6 {
                c.fetch(stream, &mut buf).unwrap();
                all.extend_from_slice(&buf);
            }
            (stream, all)
        }));
    }
    for h in handles {
        let (stream, got) = h.join().unwrap();
        let g = stream / 4;
        let mut s = ThunderingStream::new(splitmix64(42 ^ g), stream);
        let expect: Vec<u32> = (0..got.len()).map(|_| s.next_u32()).collect();
        assert_eq!(got, expect, "stream {stream}");
    }
}

#[test]
fn fetch_many_blocks_match_batch_engine_across_shard_counts() {
    // The batched API must return the same bits no matter how groups are
    // spread over shards (1, 2, or 5 shards over 5 groups) — the
    // shard-affine tile-interleaved drain must not reorder any group's
    // tile sequence.
    for shards in [1usize, 2, 5] {
        let c = build(4, 16, shards, 20);
        let first = c.fetch_many(32).unwrap();
        let second = c.fetch_many(16).unwrap();
        assert_eq!(first.len(), 5);
        for g in 0..5usize {
            let mut batch =
                ThunderingBatch::new(splitmix64(42 ^ g as u64), 4, g as u64 * 4);
            assert_eq!(first[g], batch.tile(32), "shards {shards} group {g} block 1");
            assert_eq!(second[g], batch.tile(16), "shards {shards} group {g} block 2");
        }
    }
}

#[test]
fn prime_sized_chunks_across_shared_shards_replay_exactly() {
    // Chunk size 97 (coprime to the 16-row tile) walks the copy window
    // through every intra-tile phase; two groups share two shards.
    let c = Arc::new(build(4, 16, 2, 8));
    let mut handles = Vec::new();
    for &stream in &[1u64, 6, 3, 7] {
        let c = c.clone();
        handles.push(std::thread::spawn(move || {
            let mut all = Vec::new();
            let mut buf = vec![0u32; 97];
            for _ in 0..5 {
                c.fetch(stream, &mut buf).unwrap();
                all.extend_from_slice(&buf);
            }
            (stream, all)
        }));
    }
    for h in handles {
        let (stream, got) = h.join().unwrap();
        let g = stream / 4;
        let mut s = ThunderingStream::new(splitmix64(42 ^ g), stream);
        let expect: Vec<u32> = (0..got.len()).map(|_| s.next_u32()).collect();
        assert_eq!(got, expect, "stream {stream}");
    }
}

#[test]
fn completion_front_four_consumers_thirty_two_groups_exact_delivery() {
    // The completion-front stress shape from the issue: 4 consumer
    // threads draining 32 groups through ONE CompletionQueue, tickets
    // racing through random wait_any interleavings (spiced with poll()
    // calls). Every ticket must be delivered exactly once — no losses,
    // no duplicates — and every group-block completion must be
    // bit-identical to the scalar oracle at its submission-order offset.
    let rows = 16usize;
    let width = 4usize;
    let groups = 32usize;
    let rounds = 6usize;
    let cq: Arc<CompletionQueue> = Arc::new(
        EngineBuilder::new((groups * width) as u64)
            .engine(Engine::Sharded)
            .group_width(width)
            .rows_per_tile(rows)
            .lag_window(u64::MAX / 2)
            .shards(0) // one per core: groups share shards on small hosts
            .root_seed(42)
            .build_completion()
            .map(|q| {
                assert!(q.engine_driven(), "sharded engine must hook the front");
                q
            })
            .unwrap(),
    );

    // Round-major submission: group g's r-th completion must carry rows
    // [r*rows, (r+1)*rows) of g's sequence.
    let mut round_of: HashMap<Ticket, (usize, usize)> = HashMap::new();
    for round in 0..rounds {
        for g in 0..groups {
            let (t, _cancel) = cq.submit(StreamReq::group(g, rows)).unwrap();
            round_of.insert(t, (g, round));
        }
    }

    type Harvest = Vec<(Ticket, StreamReq, Vec<u32>)>;
    let harvested: Arc<Mutex<Harvest>> = Arc::new(Mutex::new(Vec::new()));
    let mut consumers = Vec::new();
    for k in 0..4usize {
        let cq = Arc::clone(&cq);
        let harvested = Arc::clone(&harvested);
        consumers.push(std::thread::spawn(move || {
            let mut mine = 0usize;
            loop {
                // Vary the harvest pattern per consumer: some poll
                // first (pure harvest), all fall back to wait_any.
                let c = if mine % 4 == k {
                    cq.poll().or_else(|| cq.wait_any(None).unwrap())
                } else {
                    cq.wait_any(None).unwrap()
                };
                match c {
                    Some(c) => {
                        let block = c.result.expect("completion failed");
                        harvested.lock().unwrap().push((c.ticket, c.req, block));
                        mine += 1;
                    }
                    None => return mine,
                }
            }
        }));
    }
    let per_consumer: Vec<usize> =
        consumers.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(
        per_consumer.iter().sum::<usize>(),
        groups * rounds,
        "collective harvest must cover every ticket: {per_consumer:?}"
    );

    let mut seen = harvested.lock().unwrap();
    seen.sort_by_key(|(t, _, _)| *t);
    assert_eq!(seen.len(), groups * rounds, "no ticket lost");
    for w in seen.windows(2) {
        assert_ne!(w[0].0, w[1].0, "no ticket duplicated");
    }
    // Bit-identical scalar replay, per group in submission order.
    let mut oracles: Vec<ThunderingBatch> = (0..groups)
        .map(|g| ThunderingBatch::new(splitmix64(42 ^ g as u64), width, (g * width) as u64))
        .collect();
    let mut next_round = vec![0usize; groups];
    for (ticket, _req, block) in seen.iter() {
        let (g, round) = round_of.remove(ticket).expect("unknown ticket completed");
        assert_eq!(
            next_round[g], round,
            "group {g} completed out of submission order"
        );
        next_round[g] += 1;
        assert_eq!(block, &oracles[g].tile(rows), "group {g} round {round}");
    }
    assert!(round_of.is_empty(), "unharvested tickets: {round_of:?}");
}

#[test]
fn concurrent_fetch_many_callers_partition_cleanly() {
    // Two threads hammering the all-groups batched API: the up-front
    // index-ordered drain locking must hand out disjoint, in-order row
    // ranges — the union must replay each group's tile sequence exactly.
    let c = Arc::new(build(4, 16, 2, 8));
    let mut handles = Vec::new();
    for _ in 0..2 {
        let c = c.clone();
        handles.push(std::thread::spawn(move || {
            let mut mine = Vec::new();
            for _ in 0..4 {
                mine.push(c.fetch_many(32).unwrap());
            }
            mine
        }));
    }
    let mut per_group_rows: Vec<Vec<Vec<u32>>> = vec![Vec::new(); 2];
    for h in handles {
        for batch in h.join().unwrap() {
            for (g, block) in batch.into_iter().enumerate() {
                per_group_rows[g].push(block);
            }
        }
    }
    // 8 blocks of 32 rows per group, in *some* interleaving; sorting by
    // first element is not valid (order matters), so instead check that
    // the multiset of blocks equals the split of the 256-row replay.
    for (g, blocks) in per_group_rows.iter().enumerate() {
        let mut batch = ThunderingBatch::new(splitmix64(42 ^ g as u64), 4, g as u64 * 4);
        let full = batch.tile(8 * 32);
        let mut expected: Vec<&[u32]> = full.chunks(32 * 4).collect();
        for block in blocks {
            let pos = expected
                .iter()
                .position(|e| *e == block.as_slice())
                .unwrap_or_else(|| panic!("group {g}: block not found in replay"));
            expected.remove(pos);
        }
        assert!(expected.is_empty(), "group {g}: replay not fully covered");
    }
}
