//! Thread-stress determinism for the sharded parallel engine: many
//! concurrent clients fetching interleaved, non-aligned chunk sizes from a
//! [`ParallelCoordinator`] must receive output **bit-identical** to scalar
//! `ThunderingStream` replay — the cross-shard, prefetching extension of
//! `coordinator::tests::concurrent_fetches_consistent`.

use std::sync::Arc;

use thundering::coordinator::{ParallelCoordinator, ShardedConfig};
use thundering::prng::{splitmix64, Prng32, ThunderingBatch, ThunderingStream};

fn config(width: usize, rows: usize, shards: usize) -> ShardedConfig {
    ShardedConfig {
        group_width: width,
        rows_per_tile: rows,
        lag_window: u64::MAX / 2,
        prefetch_depth: 2,
        shards,
        root_seed: 42,
    }
}

#[test]
fn sixteen_clients_bit_identical_to_scalar_replay() {
    // 16 groups of 8 streams; 16 clients, each hammering a different
    // (group, lane) pair with varying chunk sizes that straddle the
    // 64-row tile boundary in every possible phase. Shard count is auto
    // (one per core), so groups share shards on small hosts — the
    // interleaving this test is designed to shake out.
    let c = Arc::new(ParallelCoordinator::new(config(8, 64, 0), 128).unwrap());
    let mut handles = Vec::new();
    for t in 0..16u64 {
        let c = c.clone();
        handles.push(std::thread::spawn(move || {
            let stream = t * 8 + (t % 8);
            let chunks = [257usize, 63, 1024, 1, 500, 129];
            let mut all = Vec::new();
            for (i, &n) in chunks.iter().cycle().take(12).enumerate() {
                let mut buf = vec![0u32; n + (i % 3)];
                c.fetch(stream, &mut buf).unwrap();
                all.extend_from_slice(&buf);
            }
            (stream, all)
        }));
    }
    for h in handles {
        let (stream, got) = h.join().unwrap();
        let g = stream / 8;
        let mut s = ThunderingStream::new(splitmix64(42 ^ g), stream);
        let expect: Vec<u32> = (0..got.len()).map(|_| s.next_u32()).collect();
        assert_eq!(got, expect, "stream {stream}");
    }
}

#[test]
fn clients_sharing_groups_stay_bit_identical() {
    // Two clients per group, different lanes: the drain lock serializes
    // them while the shard prefetches; both lanes must replay exactly.
    let c = Arc::new(ParallelCoordinator::new(config(4, 32, 2), 16).unwrap());
    let mut handles = Vec::new();
    for t in 0..8u64 {
        let c = c.clone();
        handles.push(std::thread::spawn(move || {
            let stream = (t / 2) * 4 + (t % 2) * 3; // lanes 0 and 3
            let mut all = Vec::new();
            let mut buf = vec![0u32; 377];
            for _ in 0..6 {
                c.fetch(stream, &mut buf).unwrap();
                all.extend_from_slice(&buf);
            }
            (stream, all)
        }));
    }
    for h in handles {
        let (stream, got) = h.join().unwrap();
        let g = stream / 4;
        let mut s = ThunderingStream::new(splitmix64(42 ^ g), stream);
        let expect: Vec<u32> = (0..got.len()).map(|_| s.next_u32()).collect();
        assert_eq!(got, expect, "stream {stream}");
    }
}

#[test]
fn fetch_many_blocks_match_batch_engine_across_shard_counts() {
    // The batched API must return the same bits no matter how groups are
    // spread over shards (1, 2, or 5 shards over 5 groups).
    for shards in [1usize, 2, 5] {
        let c = ParallelCoordinator::new(config(4, 16, shards), 20).unwrap();
        let first = c.fetch_many(32).unwrap();
        let second = c.fetch_many(16).unwrap();
        assert_eq!(first.len(), 5);
        for g in 0..5usize {
            let mut batch =
                ThunderingBatch::new(splitmix64(42 ^ g as u64), 4, g as u64 * 4);
            assert_eq!(first[g], batch.tile(32), "shards {shards} group {g} block 1");
            assert_eq!(second[g], batch.tile(16), "shards {shards} group {g} block 2");
        }
    }
}

#[test]
fn prime_sized_chunks_across_shared_shards_replay_exactly() {
    // Chunk size 97 (coprime to the 16-row tile) walks the copy window
    // through every intra-tile phase; two groups share two shards.
    let c = Arc::new(ParallelCoordinator::new(config(4, 16, 2), 8).unwrap());
    let mut handles = Vec::new();
    for &stream in &[1u64, 6, 3, 7] {
        let c = c.clone();
        handles.push(std::thread::spawn(move || {
            let mut all = Vec::new();
            let mut buf = vec![0u32; 97];
            for _ in 0..5 {
                c.fetch(stream, &mut buf).unwrap();
                all.extend_from_slice(&buf);
            }
            (stream, all)
        }));
    }
    for h in handles {
        let (stream, got) = h.join().unwrap();
        let g = stream / 4;
        let mut s = ThunderingStream::new(splitmix64(42 ^ g), stream);
        let expect: Vec<u32> = (0..got.len()).map(|_| s.next_u32()).collect();
        assert_eq!(got, expect, "stream {stream}");
    }
}
