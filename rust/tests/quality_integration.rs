//! Integration: statistical quality of the numbers actually served by the
//! coordinator (artifact path) — the end-to-end version of Table 2's
//! protocol at CI scale. Served streams feed the battery through
//! `StreamHandle`'s `Prng32` view.
//! Requires the `xla` feature (real PJRT bindings) plus `make artifacts`.

#![cfg(feature = "xla")]

use std::sync::Arc;

use thundering::stats::{mini_crush, Interleaved, Scale};
use thundering::{Engine, EngineBuilder, StreamHandle, StreamSource};

fn artifacts_dir() -> String {
    std::env::var("THUNDERING_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")))
}

fn pjrt_source() -> Arc<dyn StreamSource> {
    EngineBuilder::new(64)
        .engine(Engine::Pjrt { artifacts_dir: artifacts_dir() })
        .group_width(64)
        .rows_per_tile(1024)
        .lag_window(1 << 22) // single consumer races ahead of lanes
        .build_arc()
        .unwrap()
}

#[test]
fn served_stream_passes_quick_battery() {
    let c = pjrt_source();
    let mut s = StreamHandle::new(c, 7).unwrap().with_chunk(8192);
    let report = mini_crush(&mut s, Scale::Quick);
    assert_eq!(report.failures(), 0, "{}", report.summary());
}

#[test]
fn served_interleaved_streams_pass_quick_battery() {
    // Inter-stream protocol (Sec. 5.1.3): round-robin interleave 8 served
    // streams and test the combined sequence.
    let c = pjrt_source();
    let streams: Vec<StreamHandle> = (0..8)
        .map(|i| StreamHandle::new(c.clone(), i * 8).unwrap().with_chunk(8192))
        .collect();
    let mut il = Interleaved::new(streams);
    let report = mini_crush(&mut il, Scale::Quick);
    assert_eq!(report.failures(), 0, "{}", report.summary());
}
