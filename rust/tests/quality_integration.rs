//! Integration: statistical quality of the numbers actually served by the
//! coordinator (artifact path) — the end-to-end version of Table 2's
//! protocol at CI scale.
//! Requires the `xla` feature (real PJRT bindings) plus `make artifacts`.

#![cfg(feature = "xla")]

use thundering::coordinator::{Config, Coordinator, Engine};
use thundering::prng::Prng32;
use thundering::stats::{mini_crush, Interleaved, Scale};

fn artifacts_dir() -> String {
    std::env::var("THUNDERING_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")))
}

/// Adapter: a coordinator stream as a Prng32 for the battery.
struct ServedStream {
    c: std::sync::Arc<Coordinator>,
    stream: u64,
    buf: Vec<u32>,
    pos: usize,
}

impl ServedStream {
    fn new(c: std::sync::Arc<Coordinator>, stream: u64) -> Self {
        Self { c, stream, buf: Vec::new(), pos: 0 }
    }
}

impl Prng32 for ServedStream {
    fn next_u32(&mut self) -> u32 {
        if self.pos == self.buf.len() {
            self.buf.resize(8192, 0);
            self.c.fetch(self.stream, &mut self.buf).expect("fetch");
            self.pos = 0;
        }
        let v = self.buf[self.pos];
        self.pos += 1;
        v
    }

    fn name(&self) -> &'static str {
        "served-thundering"
    }
}

#[test]
fn served_stream_passes_quick_battery() {
    let c = std::sync::Arc::new(
        Coordinator::new(
            Config {
                engine: Engine::Pjrt { artifacts_dir: artifacts_dir() },
                group_width: 64,
                rows_per_tile: 1024,
                lag_window: 1 << 22, // single consumer races ahead of lanes
                ..Default::default()
            },
            64,
        )
        .unwrap(),
    );
    let mut s = ServedStream::new(c, 7);
    let report = mini_crush(&mut s, Scale::Quick);
    assert_eq!(report.failures(), 0, "{}", report.summary());
}

#[test]
fn served_interleaved_streams_pass_quick_battery() {
    // Inter-stream protocol (Sec. 5.1.3): round-robin interleave 8 served
    // streams and test the combined sequence.
    let c = std::sync::Arc::new(
        Coordinator::new(
            Config {
                engine: Engine::Pjrt { artifacts_dir: artifacts_dir() },
                group_width: 64,
                rows_per_tile: 1024,
                lag_window: 1 << 22,
                ..Default::default()
            },
            64,
        )
        .unwrap(),
    );
    let streams: Vec<ServedStream> =
        (0..8).map(|i| ServedStream::new(c.clone(), i * 8)).collect();
    let mut il = Interleaved::new(streams);
    let report = mini_crush(&mut il, Scale::Quick);
    assert_eq!(report.failures(), 0, "{}", report.summary());
}
