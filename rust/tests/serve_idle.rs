//! No-spin regression for the serving layer, isolated in its own test
//! binary so `/proc/self/task` contains only this server's `thng-`
//! threads: the thread count must be O(cores) — independent of the
//! session count — and an idle server must burn ~zero CPU (a polling
//! sleep loop shows up as tens of scheduler ticks here).
#![cfg(target_os = "linux")]

use std::sync::Arc;
use std::time::Duration;

use thundering::serve::{RemoteClient, RemoteSource, ServeConfig, Server};
use thundering::{Engine, EngineBuilder, StreamSource};

/// Every serve thread carries a `thng-` comm prefix (≤ 15 chars, the
/// kernel's comm limit). Returns `(comm, utime + stime)` per thread,
/// in clock ticks, from `/proc/self/task/*/stat`.
fn thng_threads() -> Vec<(String, u64)> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir("/proc/self/task").unwrap() {
        let path = entry.unwrap().path().join("stat");
        // A thread may exit between readdir and read; skip the gone.
        let Ok(stat) = std::fs::read_to_string(&path) else { continue };
        // comm sits in parens and may itself contain spaces; everything
        // after the closing paren is space-separated, with utime and
        // stime at (1-based stat) fields 14 and 15.
        let (Some(open), Some(close)) = (stat.find('('), stat.rfind(')')) else { continue };
        let comm = &stat[open + 1..close];
        if !comm.starts_with("thng-") {
            continue;
        }
        let rest: Vec<&str> = stat[close + 2..].split_whitespace().collect();
        let utime: u64 = rest[11].parse().unwrap();
        let stime: u64 = rest[12].parse().unwrap();
        out.push((comm.to_string(), utime + stime));
    }
    out
}

#[test]
fn serve_threads_are_o_cores_and_do_not_spin_at_idle() {
    let source: Arc<dyn StreamSource> = EngineBuilder::new(4)
        .engine(Engine::Native)
        .group_width(4)
        .rows_per_tile(4)
        .lag_window(u64::MAX / 2)
        .root_seed(42)
        .build_arc()
        .unwrap();
    let server = Server::start(
        source,
        "127.0.0.1:0",
        ServeConfig { workers: 2, ..ServeConfig::default() },
    )
    .unwrap();

    // O(cores), not O(sessions): accept + poll + 2 workers + 1 reactor.
    let baseline = thng_threads();
    assert_eq!(baseline.len(), 5, "serve thread set: {baseline:?}");
    for want in ["thng-accept", "thng-poll", "thng-worker-0", "thng-worker-1", "thng-reactor-0"] {
        assert!(
            baseline.iter().any(|(name, _)| name == want),
            "missing {want} in {baseline:?}"
        );
    }

    let clients: Vec<RemoteClient> = (0..32)
        .map(|_| RemoteClient::connect(server.local_addr()).unwrap())
        .collect();
    assert_eq!(thng_threads().len(), 5, "32 more sessions added zero threads");

    // Warm the path once so every thread has woken at least once, then
    // let the whole server go idle with 33 open sessions.
    let remote = RemoteSource::connect(server.local_addr()).unwrap();
    remote.fetch_block(0, 4).unwrap();

    let before: u64 = thng_threads().iter().map(|(_, t)| t).sum();
    std::thread::sleep(Duration::from_millis(600));
    let after: u64 = thng_threads().iter().map(|(_, t)| t).sum();
    // Parked threads burn nothing over 600 ms; a busy-wait or a tight
    // sleep-poll loop burns tens of ticks. Allow 5 (~50 ms at the usual
    // 100 Hz) for scheduler noise and the poll thread's backed-off tick.
    assert!(
        after.saturating_sub(before) <= 5,
        "idle serve threads burned {} ticks over 600 ms",
        after.saturating_sub(before)
    );

    drop(remote);
    for client in clients {
        client.bye().unwrap();
    }
    server.wait_sessions_closed(33);
}
