//! Adversarial self-test for the cross-stream battery (ISSUE 10): a
//! battery that cannot *reject* known dependence is untrustworthy, so
//! each deliberately dependent source here must FAIL the shipped `ci`
//! profile — two handles on the same stream, a pair of same-seed
//! un-decorrelated LCGs (the paper's Table 3 baseline defect), and a
//! shift-by-k copy. The control (properly decorrelated ThundeRiNG
//! streams) must pass the very same profile.

use thundering::prng::thundering::{Ablation, AblatedStream};
use thundering::prng::{Prng32, ThunderingStream};
use thundering::quality::{run_battery, Profile};
use thundering::stats::Verdict;

fn drain(g: &mut dyn Prng32, len: usize) -> Vec<u32> {
    (0..len).map(|_| g.next_u32()).collect()
}

fn failed_names(report: &thundering::quality::QualityReport) -> Vec<String> {
    report
        .results
        .iter()
        .filter(|r| r.verdict() == Verdict::Fail)
        .map(|r| r.name.clone())
        .collect()
}

#[test]
fn control_decorrelated_streams_pass_the_ci_profile() {
    let streams: Vec<Vec<u32>> = (0..8)
        .map(|i| drain(&mut ThunderingStream::new(42, i as u64), 4096))
        .collect();
    let report = run_battery(&streams, &Profile::ci()).unwrap();
    assert!(report.passed(), "control must pass: {}", report.summary());
    assert_eq!(report.results.len(), 4);
}

#[test]
fn two_handles_on_the_same_stream_fail() {
    // The serve-layer bug this models: two leases that alias one stream.
    let one = drain(&mut ThunderingStream::new(42, 7), 4096);
    let streams = vec![one.clone(), one];
    let report = run_battery(&streams, &Profile::ci()).unwrap();
    assert!(!report.passed(), "identical streams must fail: {}", report.summary());
    let failed = failed_names(&report);
    for name in ["cross_corr", "cross_birthday", "cross_rank", "cross_hwd"] {
        assert!(failed.iter().any(|f| f == name), "{name} should fail, got {failed:?}");
    }
}

#[test]
fn same_seed_undecorrelated_lcg_pair_fails() {
    // Table 3's motivating defect: truncated same-root LCG streams whose
    // leaf constants nearly agree in the top bits are ~perfectly
    // correlated (this pair sits at Pearson ~0.999) — exactly what the
    // decorrelator exists to fix, and exactly what the battery must see.
    let a = drain(&mut AblatedStream::new(42, 0, Ablation::LcgBaseline), 4096);
    let b = drain(&mut AblatedStream::new(42, 1292, Ablation::LcgBaseline), 4096);
    let report = run_battery(&[a, b], &Profile::ci()).unwrap();
    assert!(!report.passed(), "correlated LCG pair must fail: {}", report.summary());
    let failed = failed_names(&report);
    assert!(
        failed.iter().any(|f| f == "cross_corr"),
        "the correlation test should catch the LCG pair, got {failed:?}"
    );
}

#[test]
fn shift_by_k_copy_fails() {
    let base = drain(&mut ThunderingStream::new(42, 3), 4200);
    let shifted: Vec<u32> = base[3..3 + 4096].to_vec();
    let report = run_battery(&[base[..4096].to_vec(), shifted], &Profile::ci()).unwrap();
    assert!(!report.passed(), "shifted copy must fail: {}", report.summary());
    let failed = failed_names(&report);
    assert!(
        failed.iter().any(|f| f == "cross_hwd"),
        "the lagged HWD probe should catch the shift, got {failed:?}"
    );
}

#[test]
fn decorrelated_ablation_column_passes_where_the_baseline_fails() {
    // The battery reproduces the paper's ablation story end to end: the
    // same stream pair under the full pipeline is independent.
    let a = drain(&mut AblatedStream::new(42, 0, Ablation::Full), 4096);
    let b = drain(&mut AblatedStream::new(42, 1292, Ablation::Full), 4096);
    let report = run_battery(&[a, b], &Profile::ci()).unwrap();
    assert!(report.passed(), "full-pipeline pair must pass: {}", report.summary());
}
