//! End-to-end driver (DESIGN.md "E2E"): a MISRN *service* — N client
//! threads issue batched fetches against any engine behind the
//! `StreamSource` surface; we report delivered throughput, request
//! latency percentiles, and a statistical spot-check of the served
//! numbers. Results are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example stream_service -- \
//!     [--clients 8] [--requests 64] [--chunk 65536] \
//!     [--engine pjrt|native|sharded]
//! ```

use std::time::Instant;

use thundering::stats::{mini_crush, Scale};
use thundering::util::cli::Args;
use thundering::{Engine, EngineBuilder, StreamHandle};

fn main() -> anyhow::Result<()> {
    let args =
        Args::parse(std::env::args().skip(1), &["clients", "requests", "chunk", "engine"])?;
    let clients = args.get_usize("clients", 8)?;
    let requests = args.get_usize("requests", 64)?;
    let chunk = args.get_usize("chunk", 65536)?;
    // --native is kept as a shorthand for --engine native.
    let engine_name =
        if args.flag("native") { "native" } else { args.get_or("engine", "pjrt") };

    let engine = match engine_name {
        "native" => Engine::Native,
        "sharded" => Engine::Sharded,
        "pjrt" => Engine::Pjrt {
            artifacts_dir: std::env::var("THUNDERING_ARTIFACTS")
                .unwrap_or_else(|_| "artifacts".into()),
        },
        other => anyhow::bail!("unknown engine {other:?}"),
    };
    let n_streams = (clients as u64).next_power_of_two().max(4) * 64;
    let c = EngineBuilder::new(n_streams)
        .engine(engine)
        .group_width(64)
        .rows_per_tile(1024)
        .lag_window(1 << 22)
        .build_arc()?;
    println!(
        "serving {} streams on {}, {clients} clients x {requests} requests x {chunk} numbers",
        n_streams,
        c.engine_kind(),
    );

    // Client pattern: each client owns one state-sharing *group* and
    // consumes whole row blocks (the Monte-Carlo pattern — all 64 lanes
    // used). Fetching a single lane is supported but wasteful by design:
    // state sharing advances the whole group (see coordinator docs).
    let rows_per_request = (chunk / 64).max(1024);
    let t0 = Instant::now();
    let mut latencies: Vec<f64> = Vec::new();
    let handles: Vec<_> = (0..clients)
        .map(|k| {
            let c = c.clone();
            std::thread::spawn(move || {
                let group = k % c.n_groups();
                let mut lats = Vec::with_capacity(requests);
                for _ in 0..requests {
                    let t = Instant::now();
                    let block = c.fetch_block(group, rows_per_request).expect("fetch");
                    lats.push(t.elapsed().as_secs_f64());
                    std::hint::black_box(&block);
                }
                lats
            })
        })
        .collect();
    for h in handles {
        latencies.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| latencies[((latencies.len() - 1) as f64 * p) as usize];
    let total_numbers = (clients * requests * rows_per_request * 64) as f64;
    println!(
        "wall = {wall:.3}s  delivered = {:.1}M numbers  throughput = {:.1} M/s ({:.4} Gb/s)",
        total_numbers / 1e6,
        total_numbers / wall / 1e6,
        total_numbers * 32.0 / wall / 1e9
    );
    println!(
        "request latency: p50 = {:.3} ms  p95 = {:.3} ms  p99 = {:.3} ms  max = {:.3} ms",
        pct(0.50) * 1e3,
        pct(0.95) * 1e3,
        pct(0.99) * 1e3,
        pct(1.0) * 1e3
    );
    println!("metrics: {}", c.metrics());

    // Quality spot-check on a freshly served stream: a StreamHandle is a
    // Prng32, so it feeds the battery directly.
    let mut s = StreamHandle::new(c.clone(), 1)?.with_chunk(8192);
    let report = mini_crush(&mut s, Scale::Quick);
    println!("served-stream quality: {}", report.summary());
    assert!(report.passed(), "served numbers failed the battery!");
    Ok(())
}
