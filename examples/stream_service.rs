//! End-to-end driver (DESIGN.md "E2E"): a MISRN *service* on real AOT
//! artifacts — N client threads issue batched fetches against the
//! coordinator; we report delivered throughput, request latency
//! percentiles, and a statistical spot-check of the served numbers.
//! Results are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example stream_service -- \
//!     [--clients 8] [--requests 64] [--chunk 65536] [--native]
//! ```

use std::sync::Arc;
use std::time::Instant;

use thundering::coordinator::{Config, Coordinator, Engine};
use thundering::stats::{mini_crush, Scale};
use thundering::util::cli::Args;

struct Served {
    c: Arc<Coordinator>,
    stream: u64,
    buf: Vec<u32>,
    pos: usize,
}

impl thundering::prng::Prng32 for Served {
    fn next_u32(&mut self) -> u32 {
        if self.pos == self.buf.len() {
            self.buf.resize(8192, 0);
            self.c.fetch(self.stream, &mut self.buf).expect("fetch");
            self.pos = 0;
        }
        let v = self.buf[self.pos];
        self.pos += 1;
        v
    }
    fn name(&self) -> &'static str {
        "served"
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["clients", "requests", "chunk"])?;
    let clients = args.get_usize("clients", 8)?;
    let requests = args.get_usize("requests", 64)?;
    let chunk = args.get_usize("chunk", 65536)?;
    let native = args.flag("native");

    let engine = if native {
        Engine::Native
    } else {
        Engine::Pjrt {
            artifacts_dir: std::env::var("THUNDERING_ARTIFACTS")
                .unwrap_or_else(|_| "artifacts".into()),
        }
    };
    let n_streams = (clients as u64).next_power_of_two().max(4) * 64;
    let c = Arc::new(Coordinator::new(
        Config {
            engine,
            group_width: 64,
            rows_per_tile: 1024,
            lag_window: 1 << 22,
            ..Default::default()
        },
        n_streams,
    )?);
    println!(
        "serving {} streams on {} (artifact {:?}), {clients} clients x {requests} requests x {chunk} numbers",
        n_streams,
        if native { "native" } else { "pjrt" },
        c.artifact()
    );

    // Client pattern: each client owns one state-sharing *group* and
    // consumes whole row blocks (the Monte-Carlo pattern — all 64 lanes
    // used). Fetching a single lane is supported but wasteful by design:
    // state sharing advances the whole group (see coordinator docs).
    let rows_per_request = (chunk / 64).max(1024);
    let t0 = Instant::now();
    let mut latencies: Vec<f64> = Vec::new();
    let handles: Vec<_> = (0..clients)
        .map(|k| {
            let c = c.clone();
            std::thread::spawn(move || {
                let group = k % c.n_groups();
                let mut lats = Vec::with_capacity(requests);
                for _ in 0..requests {
                    let t = Instant::now();
                    let block = c.fetch_group_block(group, rows_per_request).expect("fetch");
                    lats.push(t.elapsed().as_secs_f64());
                    std::hint::black_box(&block);
                }
                lats
            })
        })
        .collect();
    for h in handles {
        latencies.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| latencies[((latencies.len() - 1) as f64 * p) as usize];
    let total_numbers = (clients * requests * rows_per_request * 64) as f64;
    println!(
        "wall = {wall:.3}s  delivered = {:.1}M numbers  throughput = {:.1} M/s ({:.4} Gb/s)",
        total_numbers / 1e6,
        total_numbers / wall / 1e6,
        total_numbers * 32.0 / wall / 1e9
    );
    println!(
        "request latency: p50 = {:.3} ms  p95 = {:.3} ms  p99 = {:.3} ms  max = {:.3} ms",
        pct(0.50) * 1e3,
        pct(0.95) * 1e3,
        pct(0.99) * 1e3,
        pct(1.0) * 1e3
    );
    println!("metrics: {}", c.metrics());

    // Quality spot-check on a freshly served stream.
    let mut s = Served { c: c.clone(), stream: 1, buf: Vec::new(), pos: 0 };
    let report = mini_crush(&mut s, Scale::Quick);
    println!("served-stream quality: {}", report.summary());
    assert!(report.passed(), "served numbers failed the battery!");
    Ok(())
}
