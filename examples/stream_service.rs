//! End-to-end driver (DESIGN.md "E2E"): a MISRN *service* behind the
//! completion front — 64 state-sharing groups served from just 2
//! consumer threads through one `CompletionQueue`. The consumers submit
//! group-block requests round-robin and harvest completions as the
//! sharded engine's workers finish them; no thread-per-group, no
//! blocking fetch per group. We report delivered throughput, the
//! per-consumer harvest split, and verify group 0's completions
//! bit-identically against the scalar oracle. Results are recorded in
//! EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --example stream_service -- \
//!     [--groups 64] [--consumers 2] [--rounds 4] [--rows 1024] \
//!     [--engine sharded|native|pjrt]
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use thundering::prng::{splitmix64, ThunderingBatch};
use thundering::stats::{mini_crush, Scale};
use thundering::util::cli::Args;
use thundering::{Engine, EngineBuilder, ReqTarget, Request, StreamHandle, StreamReq};

const WIDTH: usize = 64;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(
        std::env::args().skip(1),
        &["groups", "consumers", "rounds", "rows", "engine"],
    )?;
    let groups = args.get_usize("groups", 64)?;
    let consumers = args.get_usize("consumers", 2)?.max(1);
    let rounds = args.get_usize("rounds", 4)?;
    let rows = args.get_usize("rows", 1024)?;
    // --native is kept as a shorthand for --engine native.
    let engine_name =
        if args.flag("native") { "native" } else { args.get_or("engine", "sharded") };
    let engine = match engine_name {
        "native" => Engine::Native,
        "sharded" => Engine::Sharded,
        "pjrt" => Engine::Pjrt {
            artifacts_dir: std::env::var("THUNDERING_ARTIFACTS")
                .unwrap_or_else(|_| "artifacts".into()),
        },
        other => anyhow::bail!("unknown engine {other:?}"),
    };

    let cq = EngineBuilder::new((groups * WIDTH) as u64)
        .engine(engine)
        .group_width(WIDTH)
        .rows_per_tile(rows.clamp(1, 1024))
        .lag_window(u64::MAX / 2)
        .build_completion()?;
    println!(
        "serving {} streams ({groups} groups x {WIDTH}) on {}, \
         {consumers} consumers x {} overlapped requests (engine-driven: {})",
        groups * WIDTH,
        cq.source().engine_kind(),
        groups * rounds,
        cq.engine_driven(),
    );

    // Submission: every group's blocks, round-major, from one thread —
    // per-group completion order therefore equals round order, which is
    // what lets us verify any group against the scalar oracle below.
    let t0 = Instant::now();
    let mut round_of = std::collections::HashMap::new();
    for round in 0..rounds {
        for g in 0..groups {
            let (ticket, _cancel) = cq.submit(StreamReq::group(g, rows))?;
            round_of.insert(ticket, round);
        }
    }

    // Harvest: `consumers` threads collectively drain every completion
    // exactly once, keeping only group 0's blocks for verification.
    let delivered = AtomicU64::new(0);
    let (counts, kept) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..consumers)
            .map(|_| {
                s.spawn(|| {
                    let mut harvested = 0u64;
                    let mut group0 = Vec::new();
                    while let Ok(Some(c)) = cq.wait_any(None) {
                        let block = c.result.expect("completion failed");
                        delivered.fetch_add(block.len() as u64, Ordering::Relaxed);
                        harvested += 1;
                        if c.req.target() == ReqTarget::Group(0) {
                            group0.push((c.ticket, block));
                        } else {
                            std::hint::black_box(&block);
                        }
                    }
                    (harvested, group0)
                })
            })
            .collect();
        let mut counts = Vec::new();
        let mut kept = Vec::new();
        for h in handles {
            let (n, g0) = h.join().expect("consumer panicked");
            counts.push(n);
            kept.extend(g0);
        }
        (counts, kept)
    });
    let wall = t0.elapsed().as_secs_f64();
    let total = delivered.load(Ordering::Relaxed) as f64;
    println!(
        "wall = {wall:.3}s  delivered = {:.1}M numbers  throughput = {:.1} M/s ({:.4} Gb/s)",
        total / 1e6,
        total / wall / 1e6,
        total * 32.0 / wall / 1e9
    );
    println!(
        "harvest split across consumers: {counts:?} (total {} completions)",
        counts.iter().sum::<u64>()
    );
    anyhow::ensure!(
        counts.iter().sum::<u64>() == (groups * rounds) as u64,
        "every ticket must complete exactly once"
    );

    // Verification: group 0's completions, in ticket (= submission)
    // order, must replay the scalar oracle seamlessly.
    let mut kept = kept;
    kept.sort_by_key(|(ticket, _)| *ticket);
    let mut oracle = ThunderingBatch::new(splitmix64(42), WIDTH, 0);
    for (round, (ticket, block)) in kept.iter().enumerate() {
        anyhow::ensure!(
            round_of.get(ticket) == Some(&round),
            "group 0 completed out of submission order"
        );
        anyhow::ensure!(
            *block == oracle.tile(rows),
            "group 0 round {round} diverged from the scalar oracle"
        );
    }
    println!("group 0: {} rounds bit-identical to the scalar replay", kept.len());
    println!("metrics: {}", cq.source().metrics());

    // Lifecycle demo: an already-expired deadline resolves as a typed
    // Err completion *without consuming stream state* — the deadline
    // sweep retires the request before any executor can claim it, so
    // the next fill continues group 0's sequence exactly where the
    // verified rounds left it.
    let (expired, _cancel) =
        cq.submit(Request::group(0).rows(rows).deadline(Duration::ZERO))?;
    let c = cq.wait_for(expired, None)?.expect("expired ticket still resolves");
    anyhow::ensure!(
        c.result == Err(thundering::Error::DeadlineExceeded),
        "a zero deadline must expire the request"
    );
    let (next, _cancel) = cq.submit(StreamReq::group(0, rows))?;
    let c = cq.wait_for(next, None)?.expect("follow-up fill resolves");
    anyhow::ensure!(
        c.result == Ok(oracle.tile(rows)),
        "the expired fill must not have consumed stream state"
    );
    println!("lifecycle: expired fill consumed nothing; follow-up replay bit-identical");

    // Quality spot-check on a freshly served stream: a StreamHandle is a
    // Prng32, so it feeds the battery directly.
    let mut s = StreamHandle::new(cq.source().clone(), 1)?.with_chunk(8192);
    let report = mini_crush(&mut s, Scale::Quick);
    println!("served-stream quality: {}", report.summary());
    anyhow::ensure!(report.passed(), "served numbers failed the battery!");
    Ok(())
}
