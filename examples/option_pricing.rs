//! Monte-Carlo option pricing end-to-end (paper Sec. 6, Fig. 9): prices a
//! ladder of strikes on the AOT Pallas tile path and checks every price
//! against the Black–Scholes closed form.
//!
//! ```sh
//! make artifacts && cargo run --release --example option_pricing
//! ```

use thundering::apps::{black_scholes_call, option_pricing};
use thundering::runtime::executor::TileExecutor;
use thundering::runtime::BsParams;
use thundering::{Engine, EngineBuilder};

fn main() -> anyhow::Result<()> {
    let artifacts =
        std::env::var("THUNDERING_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let guard = TileExecutor::spawn(artifacts, 4)?;
    let threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(8);
    let draws = 1u64 << 24;

    // Strike ladder around the money.
    println!(
        "{:>8} {:>12} {:>12} {:>10} {:>10}",
        "strike", "MC (pjrt)", "closed form", "|err|", "time (s)"
    );
    for strike in [80.0f32, 90.0, 100.0, 110.0, 120.0] {
        let params = BsParams { k: strike, ..Default::default() };
        let run = option_pricing::run_pjrt(&guard.executor, draws, 42, params)?;
        let closed = black_scholes_call(100.0, strike as f64, 0.05, 0.2, 1.0);
        println!(
            "{:>8.1} {:>12.4} {:>12.4} {:>10.2e} {:>10.4}",
            strike,
            run.result,
            closed,
            (run.result - closed).abs(),
            run.seconds
        );
    }

    // Native engine cross-check at the money, through the same
    // engine-agnostic driver the CLI uses.
    let source = EngineBuilder::new(threads as u64 * 64)
        .engine(Engine::Native)
        .root_seed(42)
        .build()?;
    let native = option_pricing::run(&*source, draws, BsParams::default())?;
    println!(
        "\nnative engine: {:.4} ({} draws in {:.3}s, {:.1} Mdraw/s)",
        native.result,
        native.draws,
        native.seconds,
        native.draws_per_sec() / 1e6
    );
    Ok(())
}
