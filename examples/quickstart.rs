//! Quickstart: build a MISRN source, take stream handles, fetch numbers.
//!
//! Runs on the native engine by default; pass `--pjrt` (with `make
//! artifacts` done) to serve from the AOT Pallas tiles instead.
//!
//! ```sh
//! cargo run --release --example quickstart [-- --pjrt]
//! ```

use thundering::{Engine, EngineBuilder, StreamHandle, StreamSource};

fn main() -> anyhow::Result<()> {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    let engine = if use_pjrt {
        Engine::Pjrt {
            artifacts_dir: std::env::var("THUNDERING_ARTIFACTS")
                .unwrap_or_else(|_| "artifacts".into()),
        }
    } else {
        Engine::Native
    };

    // 128 independent streams in two state-sharing groups of 64, behind
    // the engine-agnostic StreamSource surface.
    let source = EngineBuilder::new(128)
        .engine(engine)
        .group_width(64)
        .rows_per_tile(1024)
        .build_arc()?;
    println!("engine: {}", source.engine_kind());

    // Every stream is an independent, crush-resistant sequence; a
    // StreamHandle is the cheap per-stream client.
    for stream in [0u64, 1, 64, 127] {
        let mut handle = StreamHandle::new(source.clone(), stream)?;
        let spec = handle.spec().unwrap();
        let mut buf = [0u32; 8];
        handle.fill(&mut buf)?;
        println!("stream {:>3} (h = {:#018x}): {:?}", stream, spec.h, buf);
    }

    // Monte-Carlo-style consumption: one whole group advancing in lockstep.
    let block = source.fetch_block(1, 1024)?;
    let mean = block.iter().map(|&v| v as f64).sum::<f64>() / block.len() as f64;
    println!(
        "group block: {} numbers, mean/2^32 = {:.4} (expect ~0.5)",
        block.len(),
        mean / 2f64.powi(32)
    );

    println!("metrics: {}", source.metrics());

    // Same streams, same bits, on the sharded parallel engine: one
    // prefetching worker shard per core with double-buffered tiles
    // (DESIGN.md §3) — only the builder call changes.
    let sharded = EngineBuilder::new(128).engine(Engine::Sharded).build_arc()?;
    let blocks = sharded.fetch_many(1024)?;
    println!(
        "sharded engine served {} groups x {} numbers, metrics: {}",
        blocks.len(),
        blocks[0].len(),
        sharded.metrics()
    );

    // Iterator view over a served stream.
    let handle = StreamHandle::new(sharded.clone(), 7)?;
    let preview: Vec<u32> = handle.take(4).collect();
    println!("stream 7 continues: {preview:?}");
    Ok(())
}
