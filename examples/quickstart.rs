//! Quickstart: create a MISRN coordinator, register streams, fetch numbers.
//!
//! Runs on the native engine by default; pass `--pjrt` (with `make
//! artifacts` done) to serve from the AOT Pallas tiles instead.
//!
//! ```sh
//! cargo run --release --example quickstart [-- --pjrt]
//! ```

use thundering::coordinator::{Config, Coordinator, Engine, ParallelCoordinator, ShardedConfig};

fn main() -> anyhow::Result<()> {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    let engine = if use_pjrt {
        Engine::Pjrt {
            artifacts_dir: std::env::var("THUNDERING_ARTIFACTS")
                .unwrap_or_else(|_| "artifacts".into()),
        }
    } else {
        Engine::Native
    };

    // 128 independent streams in two state-sharing groups of 64.
    let coordinator = Coordinator::new(
        Config { engine, group_width: 64, rows_per_tile: 1024, ..Default::default() },
        128,
    )?;

    println!("engine artifact: {:?}", coordinator.artifact());

    // Every stream is an independent, crush-resistant sequence.
    for stream in [0u64, 1, 64, 127] {
        let spec = coordinator.spec(stream).unwrap();
        let mut buf = [0u32; 8];
        coordinator.fetch(stream, &mut buf)?;
        println!("stream {:>3} (h = {:#018x}): {:?}", stream, spec.h, buf);
    }

    // Monte-Carlo-style consumption: one whole group advancing in lockstep.
    let block = coordinator.fetch_group_block(1, 1024)?;
    let mean = block.iter().map(|&v| v as f64).sum::<f64>() / block.len() as f64;
    println!(
        "group block: {} numbers, mean/2^32 = {:.4} (expect ~0.5)",
        block.len(),
        mean / 2f64.powi(32)
    );

    println!("metrics: {}", coordinator.metrics());

    // Sharded parallel engine: same streams and same bits, but generation
    // runs on one shard per core with double-buffered tiles (DESIGN.md §3).
    let sharded = ParallelCoordinator::new(
        ShardedConfig { group_width: 64, root_seed: 42, ..Default::default() },
        128,
    )?;
    let blocks = sharded.fetch_many(1024)?;
    println!(
        "sharded engine: {} shards served {} groups x {} numbers, metrics: {}",
        sharded.n_shards(),
        blocks.len(),
        blocks[0].len(),
        sharded.metrics()
    );
    Ok(())
}
