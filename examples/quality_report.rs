//! Quality report: run the MiniCrush battery (Table 2's engine) on
//! ThundeRiNG and the comparator set, single-stream and interleaved.
//!
//! ```sh
//! cargo run --release --example quality_report [-- --scale standard]
//! ```

use thundering::report;
use thundering::stats::Scale;
use thundering::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["scale", "cap"])?;
    let scale = Scale::parse(args.get_or("scale", "quick"))
        .ok_or_else(|| anyhow::anyhow!("bad --scale (quick|standard|deep)"))?;
    let cap = args.get_u64("cap", 1 << 24)?;

    // Per-generator detailed battery for the flagship.
    print!("{}", report::quality_one("thundering", scale)?);
    println!();

    // The full Table 2 protocol.
    print!("{}", report::table2(scale, cap)?);
    Ok(())
}
