//! π estimation end-to-end (paper Sec. 6, Fig. 8): measured PJRT (AOT
//! Pallas tile) and native engines on this host, plus the FPGA/GPU model
//! projections the paper's figure compares.
//!
//! ```sh
//! make artifacts && cargo run --release --example pi_estimation
//! ```

use thundering::apps::gpu_model::{FPGA_PI, P100_PI};
use thundering::apps::pi;
use thundering::runtime::executor::TileExecutor;
use thundering::{Engine, EngineBuilder};

fn main() -> anyhow::Result<()> {
    let artifacts =
        std::env::var("THUNDERING_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(8);
    let guard = TileExecutor::spawn(artifacts, 4)?;

    println!(
        "{:>12} {:>12} {:>10} {:>12} {:>10} {:>14} {:>14} {:>9}",
        "draws", "pjrt (s)", "pjrt err", "native (s)", "nat err", "FPGA model(s)", "GPU model(s)", "speedup"
    );
    for shift in [20u32, 22, 24, 26] {
        let draws = 1u64 << shift;
        let pjrt = pi::run_pjrt(&guard.executor, draws, 42)?;
        // Fresh native source per row: streams restart from the origin.
        let source = EngineBuilder::new(threads as u64 * 64)
            .engine(Engine::Native)
            .root_seed(42)
            .build()?;
        let native = pi::run(&*source, draws)?;
        let samples = draws * 2;
        let f_t = FPGA_PI.exec_time(samples);
        let g_t = P100_PI.exec_time(samples);
        println!(
            "{:>12} {:>12.4} {:>10.2e} {:>12.4} {:>10.2e} {:>14.6} {:>14.6} {:>8.2}x",
            draws,
            pjrt.seconds,
            (pjrt.result - std::f64::consts::PI).abs(),
            native.seconds,
            (native.result - std::f64::consts::PI).abs(),
            f_t,
            g_t,
            g_t / f_t,
        );
    }
    println!(
        "\npaper Fig. 8 shape: FPGA beats GPU at every draw count; speedup \
         stabilizes toward ~9.15x for massive draws."
    );
    Ok(())
}
