//! Monte-Carlo π over the network: an in-process TCP server fronting
//! the sharded engine, consumed through `RemoteSource` — the same
//! engine-agnostic `apps::pi::run` driver that serves the local
//! engines, now fed across a socket, with a bit-identity check against
//! the local replay first.
//!
//! ```sh
//! cargo run --release --example remote_pi
//! ```

use std::sync::Arc;

use thundering::apps::pi;
use thundering::prng::{splitmix64, Prng32, ThunderingStream};
use thundering::serve::{RemoteSource, ServeConfig, Server};
use thundering::{Engine, EngineBuilder, StreamHandle};

/// A fresh sharded source for serving (large lag window: remote group
/// consumers drain uniformly).
fn sharded_source(
    n_streams: u64,
) -> Result<Arc<dyn thundering::StreamSource>, thundering::Error> {
    EngineBuilder::new(n_streams)
        .engine(Engine::Sharded)
        .group_width(64)
        .rows_per_tile(1024)
        .lag_window(u64::MAX / 2)
        .root_seed(42)
        .build_arc()
}

fn main() -> anyhow::Result<()> {
    let threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(8);
    let n_streams = threads as u64 * 64;

    // Part 1 — determinism over the wire: a StreamHandle on a remote
    // source must replay the scalar oracle bit for bit.
    {
        let server =
            Server::start(sharded_source(n_streams)?, "127.0.0.1:0", ServeConfig::default())?;
        let remote = Arc::new(RemoteSource::connect(server.local_addr())?);
        println!(
            "connected to {} [{} engine behind the wire], {} streams",
            server.local_addr(),
            remote.info().engine,
            remote.info().n_streams
        );
        let mut handle = StreamHandle::new(remote, 7)?;
        let mut oracle = ThunderingStream::new(splitmix64(42), 7); // group 0
        let mut via_wire = Vec::with_capacity(256);
        for _ in 0..256 {
            via_wire.push(handle.next_u32()?);
        }
        let local: Vec<u32> = (0..256).map(|_| oracle.next_u32()).collect();
        assert_eq!(via_wire, local, "remote stream diverged from the scalar replay");
        println!("stream 7 over TCP == scalar replay, 256/256 numbers bit-identical");
    }

    // Part 2 — the case study itself: π through the network-served
    // engine vs π on a local source with the same spec. Fresh server so
    // both start from the stream origins.
    let draws = 1u64 << 22;
    let server =
        Server::start(sharded_source(n_streams)?, "127.0.0.1:0", ServeConfig::default())?;
    let remote = Arc::new(RemoteSource::connect(server.local_addr())?);
    let remote_run = pi::run(&*remote, draws)?;

    let local_source = sharded_source(n_streams)?;
    let local_run = pi::run(&*local_source, draws)?;

    println!(
        "pi({} draws, remote) = {:.6}  |err| = {:.2e}  time = {:.4}s  rate = {}",
        remote_run.draws,
        remote_run.result,
        (remote_run.result - std::f64::consts::PI).abs(),
        remote_run.seconds,
        thundering::util::fmt_rate(remote_run.draws_per_sec()),
    );
    println!(
        "pi({} draws, local ) = {:.6}  |err| = {:.2e}  time = {:.4}s  rate = {}",
        local_run.draws,
        local_run.result,
        (local_run.result - std::f64::consts::PI).abs(),
        local_run.seconds,
        thundering::util::fmt_rate(local_run.draws_per_sec()),
    );
    assert_eq!(
        remote_run.result, local_run.result,
        "the network boundary must not change a single bit"
    );
    println!("remote == local estimate, bit for bit — the wire serves the same streams");
    Ok(())
}
